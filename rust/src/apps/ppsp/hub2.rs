//! Hub²-Labeling for PPSP queries (paper §5.1.2).
//!
//! Hubs are the top-k highest-degree vertices. The index stores:
//! * `hub_dist` — the k×k pairwise hub distance table `D_H`;
//! * per-vertex core-hub labels: `L_out(v)` (exit-hubs `h` with `d(h, v)`)
//!   and `L_in(v)` (entry-hubs `h` with `d(v, h)`). A hub `h` is a
//!   core-hub of `v` iff no other hub lies on any shortest path between
//!   them; for undirected graphs the two sides coincide.
//!
//! Indexing runs |H| BFS jobs *as Quegel queries* (superstep-shared), each
//! propagating the paper's `pre_H` flag. The min-plus closure of `D_H` and
//! the batched query upper bound `d_ub` are evaluated through the
//! [`MinPlus`] trait — either the pure-rust fallback or the AOT-compiled
//! Pallas kernel via PJRT (`crate::runtime::minplus`), which is the L1
//! integration point on the query hot path.
//!
//! Querying: `d_ub = min_{h_s, h_t} d(s,h_s) + D_H[h_s,h_t] + d(h_t,t)`,
//! then BiBFS restricted to non-hub vertices with the superstep cutoff
//! `1 + floor(d_ub / 2)`.

use super::bibfs::{BiAgg, BiState, BWD, FWD};
use super::{PpspQuery, UNREACHED};
use crate::coordinator::Engine;
use crate::graph::{
    Epoch, Graph, Mutation, MutationApplied, MutationBatch, VersionedGraph, VertexId,
};
use crate::metrics::EngineMetrics;
use crate::network::Cluster;
use crate::runtime::rowmin;
use crate::util::FxHashMap;
use crate::vertex::{Ctx, MasterAction, QueryApp};

/// f32 encoding of "unreachable" used by the kernels (2^31, matches
/// python/compile/kernels/ref.py and the blocked kernels'
/// [`rowmin::INF`]).
pub const F_INF: f32 = rowmin::INF;

/// Convert a hop count to the kernel encoding.
#[inline]
pub fn to_f(d: u32) -> f32 {
    if d == UNREACHED {
        F_INF
    } else {
        d as f32
    }
}

/// Convert back from the kernel encoding (clamps anything >= INF).
#[inline]
pub fn from_f(x: f32) -> u32 {
    if x >= F_INF {
        UNREACHED
    } else {
        x as u32
    }
}

/// Tropical-algebra evaluator abstraction: pure-rust fallback or the
/// PJRT-compiled Pallas kernel.
pub trait MinPlus {
    /// In-place min-plus closure of the `k×k` table `d` (repeated squaring
    /// to fixpoint).
    fn closure(&self, d: &mut [f32], k: usize);

    /// Batched upper bound: for each query row `q` of the `c×k` tables,
    /// `out[q] = min_{i,j} s[q*k+i] + d[i*k+j] + t[q*k+j]`.
    fn dub_batch(&self, s: &[f32], d: &[f32], t: &[f32], c: usize, k: usize) -> Vec<f32>;
}

/// Pure-rust reference evaluator (used when artifacts are absent and by
/// tests as the oracle for the PJRT path).
pub struct RustMinPlus;

impl MinPlus for RustMinPlus {
    fn closure(&self, d: &mut [f32], k: usize) {
        if k == 0 {
            return;
        }
        let steps = (k as f64).log2().ceil() as usize + 1;
        let mut cur = d.to_vec();
        for _ in 0..steps.max(1) {
            let mut next = cur.clone();
            for i in 0..k {
                for mid in 0..k {
                    let dm = cur[i * k + mid];
                    if dm >= F_INF {
                        continue;
                    }
                    for j in 0..k {
                        let cand = dm + cur[mid * k + j];
                        if cand < next[i * k + j] {
                            next[i * k + j] = cand;
                        }
                    }
                }
            }
            if next == cur {
                break;
            }
            cur = next;
        }
        d.copy_from_slice(&cur);
    }

    fn dub_batch(&self, s: &[f32], d: &[f32], t: &[f32], c: usize, k: usize) -> Vec<f32> {
        (0..c)
            .map(|q| {
                let mut best = F_INF;
                for i in 0..k {
                    let si = s[q * k + i];
                    if si >= F_INF {
                        continue;
                    }
                    for j in 0..k {
                        let cand = si + d[i * k + j] + t[q * k + j];
                        if cand < best {
                            best = cand;
                        }
                    }
                }
                best
            })
            .collect()
    }
}

/// Blocked-kernel evaluator: the tropical closure by repeated squaring
/// and the two-stage batched upper bound (`sd = S ⊗ D_H` via the blocked
/// min-plus matmul, then the fused row reduction against the t-side
/// rows) over the cache-tiled loops in [`crate::runtime::rowmin`]. This
/// is the default-build stand-in for the AOT-compiled Pallas artifacts
/// and the evaluator the batched admission hook runs on the query hot
/// path; [`RustMinPlus`] stays as the naive oracle it is tested against.
pub struct BlockedMinPlus;

impl MinPlus for BlockedMinPlus {
    fn closure(&self, d: &mut [f32], k: usize) {
        rowmin::closure_in_place(d, k);
    }

    fn dub_batch(&self, s: &[f32], d: &[f32], t: &[f32], c: usize, k: usize) -> Vec<f32> {
        let sd = rowmin::minplus_matmul(s, d, c, k, k);
        rowmin::tropical_rowmin(&sd, t, c, k)
    }
}

/// Hub selection criterion for directed graphs (paper: results similar;
/// experiments report in-degree).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HubSelection {
    InDegree,
    OutDegree,
    SumDegree,
}

/// The Hub² index.
pub struct Hub2Index {
    /// Hub vertex ids, rank order.
    pub hubs: Vec<VertexId>,
    /// vertex id -> hub rank.
    pub hub_rank: FxHashMap<VertexId, u16>,
    /// k×k pairwise hub distances (row i = from hub i), kernel encoding.
    pub hub_dist: Vec<f32>,
    /// L_in(v): entry-hub labels (h_rank, d(v, h)).
    pub label_in: Vec<Vec<(u16, u32)>>,
    /// L_out(v): exit-hub labels (h_rank, d(h, v)).
    pub label_out: Vec<Vec<(u16, u32)>>,
}

impl Hub2Index {
    /// Number of hubs.
    pub fn k(&self) -> usize {
        self.hubs.len()
    }

    /// True if `v` is a hub.
    #[inline]
    pub fn is_hub(&self, v: VertexId) -> bool {
        self.hub_rank.contains_key(&v)
    }

    /// Entry-hub label row of `s` (d(s, h) per hub), padded to `k_pad`.
    pub fn s_row(&self, s: VertexId, k_pad: usize) -> Vec<f32> {
        let mut row = vec![F_INF; k_pad];
        if let Some(&r) = self.hub_rank.get(&s) {
            row[r as usize] = 0.0;
        } else {
            for &(h, d) in &self.label_in[s as usize] {
                row[h as usize] = d as f32;
            }
        }
        row
    }

    /// Exit-hub label row of `t` (d(h, t) per hub), padded to `k_pad`.
    pub fn t_row(&self, t: VertexId, k_pad: usize) -> Vec<f32> {
        let mut row = vec![F_INF; k_pad];
        if let Some(&r) = self.hub_rank.get(&t) {
            row[r as usize] = 0.0;
        } else {
            for &(h, d) in &self.label_out[t as usize] {
                row[h as usize] = d as f32;
            }
        }
        row
    }

    /// Pad `hub_dist` to `k_pad×k_pad` (kernel shapes are static); padding
    /// rows/cols are INF with a 0 diagonal so they are inert.
    pub fn padded_dist(&self, k_pad: usize) -> Vec<f32> {
        let k = self.k();
        assert!(k_pad >= k);
        let mut d = vec![F_INF; k_pad * k_pad];
        for i in 0..k {
            d[i * k_pad..i * k_pad + k].copy_from_slice(&self.hub_dist[i * k..(i + 1) * k]);
        }
        for i in k..k_pad {
            d[i * k_pad + i] = 0.0;
        }
        d
    }

    /// Compute d_ub for a batch of queries via the given evaluator,
    /// padding each chunk to the evaluator-preferred batch width `c_pad`.
    pub fn dub_for(
        &self,
        queries: &[PpspQuery],
        mp: &dyn MinPlus,
        c_pad: usize,
        k_pad: usize,
    ) -> Vec<u32> {
        let k = k_pad.max(self.k());
        let d = self.padded_dist(k);
        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(c_pad.max(1)) {
            let c = c_pad.max(chunk.len());
            let mut s = vec![F_INF; c * k];
            let mut t = vec![F_INF; c * k];
            for (qi, &(qs, qt)) in chunk.iter().enumerate() {
                s[qi * k..(qi + 1) * k].copy_from_slice(&self.s_row(qs, k));
                t[qi * k..(qi + 1) * k].copy_from_slice(&self.t_row(qt, k));
            }
            let dub = mp.dub_batch(&s, &d, &t, c, k);
            for (qi, _) in chunk.iter().enumerate() {
                out.push(from_f(dub[qi]));
            }
        }
        out
    }

    /// Estimated index memory footprint in bytes (for load-time modeling).
    pub fn footprint_bytes(&self) -> usize {
        let labels: usize = self
            .label_in
            .iter()
            .chain(self.label_out.iter())
            .map(|l| l.len() * 6)
            .sum();
        self.hub_dist.len() * 4 + labels + self.hubs.len() * 4
    }
}

// ---------------------------------------------------------------------------
// Indexing: |H| BFS jobs run as Quegel queries with the pre_H flag.
// ---------------------------------------------------------------------------

/// Direction of a hub BFS pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Pass {
    /// Forward BFS from h computes d(h, v) (exit-hub side, L_out).
    Forward,
    /// Backward BFS from h computes d(v, h) (entry-hub side, L_in).
    Backward,
}

/// Per-vertex state of a hub BFS: (distance, pre_H flag).
#[derive(Debug, Clone)]
pub struct HubBfsState {
    d: u32,
    pre: bool,
}

/// The hub-BFS-as-a-query app (paper §5.1.2 "Algorithm for Indexing").
struct HubBfs<'g> {
    g: &'g Graph,
    hubs: FxHashMap<VertexId, u16>,
    pass: Pass,
    /// Optional truncation radius: stop expanding past this distance and
    /// let the min-plus closure complete D_H (fast-indexing mode).
    radius: Option<u32>,
}

impl<'g> HubBfs<'g> {
    fn nbrs(&self, v: VertexId) -> &[VertexId] {
        match self.pass {
            Pass::Forward => self.g.out(v),
            Pass::Backward => self.g.inn(v),
        }
    }
}

impl<'g> QueryApp for HubBfs<'g> {
    /// The hub vertex (query ⟨h⟩).
    type Query = VertexId;
    type VQ = HubBfsState;
    /// TRUE iff the shortest path to the sender passes through another hub.
    type Msg = bool;
    type Agg = ();
    /// All touched vertices with (v, d, pre): the "dump UDF" payload.
    type Out = Vec<(VertexId, u32, bool)>;

    fn init_activate(&self, h: &VertexId) -> Vec<VertexId> {
        vec![*h]
    }

    fn init_value(&self, h: &VertexId, v: VertexId) -> HubBfsState {
        HubBfsState {
            d: if v == *h { 0 } else { UNREACHED },
            pre: false,
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut HubBfsState) {
        let step = ctx.superstep();
        if step == 1 {
            // v == h: broadcast FALSE (no intermediate hub yet).
            for &u in self.nbrs(v) {
                ctx.send(u, false);
            }
            ctx.vote_halt();
            return;
        }
        if st.d != UNREACHED {
            ctx.vote_halt();
            return;
        }
        st.d = (step - 1) as u32;
        // pre_H(v) = TRUE iff any shortest path to v passed another hub.
        st.pre = ctx.msgs().iter().any(|&m| m);
        if self.radius.map(|r| st.d >= r).unwrap_or(false) {
            ctx.vote_halt();
            return;
        }
        // Hubs and hub-shadowed vertices taint downstream paths.
        let relay = self.hubs.contains_key(&v) || st.pre;
        for &u in self.nbrs(v) {
            ctx.send(u, relay);
        }
        ctx.vote_halt();
    }

    /// pre_H needs "any shortest path", an OR over senders — all senders
    /// are at BFS distance d-1 and deliver in the same superstep, so
    /// OR-combining per destination is exact.
    fn combine(&self, into: &mut bool, from: &bool) -> bool {
        *into |= *from;
        true
    }

    fn finish(
        &self,
        _h: &VertexId,
        touched: &mut dyn Iterator<Item = (VertexId, &HubBfsState)>,
        _agg: &(),
    ) -> Self::Out {
        let mut out = Vec::new();
        for (v, st) in touched {
            if st.d != UNREACHED {
                out.push((v, st.d, st.pre));
            }
        }
        out
    }

    fn msg_bytes(&self) -> usize {
        1
    }
}

/// Hub² index construction statistics (Table 5a / 6a rows).
#[derive(Debug, Clone, Default)]
pub struct IndexStats {
    /// Simulated seconds spent in the BFS jobs.
    pub index_time: f64,
    /// Wall seconds for the closure evaluation.
    pub closure_time: f64,
    /// Engine counters of the (last) indexing run.
    pub metrics: EngineMetrics,
}

/// Builder for [`Hub2Index`].
pub struct Hub2Indexer {
    pub k: usize,
    pub selection: HubSelection,
    /// True for graphs where in == out adjacency (stored undirected).
    pub undirected: bool,
    /// Fast-indexing mode: truncate hub BFS at this radius and recover the
    /// full D_H via the min-plus closure kernel.
    pub radius: Option<u32>,
    /// Capacity for the indexing engine (hub BFS jobs superstep-share too).
    pub capacity: usize,
}

impl Hub2Indexer {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            selection: HubSelection::InDegree,
            undirected: false,
            radius: None,
            capacity: 8,
        }
    }

    pub fn undirected(mut self, yes: bool) -> Self {
        self.undirected = yes;
        self
    }

    pub fn selection(mut self, s: HubSelection) -> Self {
        self.selection = s;
        self
    }

    pub fn radius(mut self, r: Option<u32>) -> Self {
        self.radius = r;
        self
    }

    pub fn capacity(mut self, c: usize) -> Self {
        self.capacity = c;
        self
    }

    /// Pick the top-k hubs by the configured degree criterion.
    pub fn pick_hubs(&self, g: &Graph) -> Vec<VertexId> {
        let n = g.num_vertices();
        let score = |v: VertexId| -> usize {
            match self.selection {
                HubSelection::OutDegree => g.out_degree(v),
                HubSelection::InDegree => g.in_degree(v),
                HubSelection::SumDegree => g.out_degree(v) + g.in_degree(v),
            }
        };
        let mut vs: Vec<VertexId> = (0..n as VertexId).collect();
        vs.sort_by_key(|&v| (std::cmp::Reverse(score(v)), v));
        vs.truncate(self.k.min(n));
        vs
    }

    /// Build the index. `g` must have in-edges materialized.
    pub fn build(&self, g: &Graph, cluster: Cluster, mp: &dyn MinPlus) -> (Hub2Index, IndexStats) {
        assert!(g.has_in_edges(), "Hub2Indexer requires ensure_in_edges()");
        let hubs = self.pick_hubs(g);
        self.build_with_hubs(g, hubs, cluster, mp)
    }

    /// Build the index over a **caller-chosen hub set** (rank order as
    /// given). This is the rebuild primitive of the streaming-mutation
    /// path: [`Hub2Maintainer`] freezes the hub set at index-build time
    /// (degree ranks drift under mutations, but re-picking hubs would
    /// invalidate every label at once), so the correctness baseline it is
    /// tested against must rebuild over the *same* hubs.
    pub fn build_with_hubs(
        &self,
        g: &Graph,
        hubs: Vec<VertexId>,
        cluster: Cluster,
        mp: &dyn MinPlus,
    ) -> (Hub2Index, IndexStats) {
        assert!(g.has_in_edges(), "Hub2Indexer requires ensure_in_edges()");
        let n = g.num_vertices();
        let k = hubs.len();
        let mut hub_rank = FxHashMap::default();
        for (i, &h) in hubs.iter().enumerate() {
            hub_rank.insert(h, i as u16);
        }

        let mut stats = IndexStats::default();
        let mut hub_dist = vec![F_INF; k * k];
        for i in 0..k {
            hub_dist[i * k + i] = 0.0;
        }
        let mut label_in: Vec<Vec<(u16, u32)>> = vec![Vec::new(); n];
        let mut label_out: Vec<Vec<(u16, u32)>> = vec![Vec::new(); n];

        let passes: &[Pass] = if self.undirected {
            &[Pass::Forward]
        } else {
            &[Pass::Forward, Pass::Backward]
        };
        for &pass in passes {
            let app = HubBfs {
                g,
                hubs: hub_rank.clone(),
                pass,
                radius: self.radius,
            };
            let mut eng = Engine::new(app, cluster.clone(), n).capacity(self.capacity);
            let qids: Vec<_> = hubs.iter().map(|&h| eng.submit(h)).collect();
            eng.run_until_idle();
            stats.index_time += eng.sim_time();
            stats.metrics = eng.metrics().clone();
            for (hi, &qid) in qids.iter().enumerate() {
                let res = eng
                    .results()
                    .iter()
                    .find(|r| r.qid == qid)
                    .expect("hub BFS completed");
                for &(v, d, pre) in &res.out {
                    if let Some(&vr) = hub_rank.get(&v) {
                        // Hub-to-hub distance: Forward fills row h (d(h, v)),
                        // Backward fills column h (d(v, h)).
                        match pass {
                            Pass::Forward => {
                                let cell = &mut hub_dist[hi * k + vr as usize];
                                *cell = cell.min(d as f32);
                            }
                            Pass::Backward => {
                                let cell = &mut hub_dist[vr as usize * k + hi];
                                *cell = cell.min(d as f32);
                            }
                        }
                    } else if !pre {
                        // Core-hub label (no other hub on any shortest path).
                        match pass {
                            Pass::Forward => label_out[v as usize].push((hi as u16, d)),
                            Pass::Backward => label_in[v as usize].push((hi as u16, d)),
                        }
                    }
                }
            }
        }
        if self.undirected {
            label_in = label_out.clone();
        }

        // Close D_H over hub-through-hub paths. With full BFS the table is
        // already closed (closure is then an idempotent no-op); in
        // fast-indexing (truncated) mode this recovers long-range entries.
        let t0 = std::time::Instant::now();
        mp.closure(&mut hub_dist, k);
        stats.closure_time = t0.elapsed().as_secs_f64();

        (
            Hub2Index {
                hubs,
                hub_rank,
                hub_dist,
                label_in,
                label_out,
            },
            stats,
        )
    }
}

// ---------------------------------------------------------------------------
// Querying: BiBFS over non-hub vertices with the d_ub cutoff.
// ---------------------------------------------------------------------------

/// Query content: (s, t, d_ub). `d_ub` is produced by
/// [`Hub2Index::dub_for`] — either explicitly by the caller, or lazily by
/// the engine's batched admission hook when submitted as
/// [`lazy_query`]`(s, t)` (the hot path: one blocked-kernel sweep fills
/// the whole admitted batch).
pub type Hub2QueryContent = (VertexId, VertexId, u32);

/// Sentinel in a [`Hub2QueryContent`]'s third slot meaning "d_ub not
/// computed yet": [`QueryApp::admit_batch`] replaces it with the real
/// bound before any per-query state is built. Deliberately distinct from
/// [`UNREACHED`], which is a *computed* bound ("the hub tables prove
/// nothing") that must keep flowing through unchanged. `dub_for` can
/// never produce this value: finite bounds are `< 2^31` and unreachable
/// ones map to [`UNREACHED`].
pub const DUB_PENDING: u32 = u32::MAX - 1;

/// A lazily-bounded query: submit this and the engine's batched admission
/// hook fills `d_ub` for the whole batch in one kernel sweep.
#[inline]
pub fn lazy_query(s: VertexId, t: VertexId) -> Hub2QueryContent {
    (s, t, DUB_PENDING)
}

/// `d_ub` at or above which a query counts as a whale for the admission
/// planner ([`QueryApp::is_heavy`]). The BiBFS cutoff bounds a query's
/// supersteps by ~`1 + d_ub/2`, so a small `d_ub` *proves* the query is
/// cheap; at 8 the index no longer guarantees a point-lookup-sized run
/// and the adaptive planner confines the query to the reserved slice.
/// [`UNREACHED`] (no cutoff at all — the worst whales) is far above this.
pub const HEAVY_DUB_THRESHOLD: u32 = 8;

/// The Hub²-indexed PPSP query app.
pub struct Hub2Query<'g, 'i> {
    g: &'g Graph,
    idx: &'i Hub2Index,
}

impl<'g, 'i> Hub2Query<'g, 'i> {
    pub fn new(g: &'g Graph, idx: &'i Hub2Index) -> Self {
        assert!(g.has_in_edges(), "Hub2Query needs in-adjacency");
        Self { g, idx }
    }

    fn broadcast(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, dir: u8) {
        if dir == FWD {
            for &u in self.g.out(v) {
                ctx.send(u, FWD);
            }
            let n = self.g.out(v).len() as u64;
            ctx.aggregate(|_, a| a.fwd_sent += n);
        } else {
            for &u in self.g.inn(v) {
                ctx.send(u, BWD);
            }
            let n = self.g.inn(v).len() as u64;
            ctx.aggregate(|_, a| a.bwd_sent += n);
        }
    }
}

impl<'g, 'i> QueryApp for Hub2Query<'g, 'i> {
    type Query = Hub2QueryContent;
    type VQ = BiState;
    type Msg = u8;
    type Agg = BiAgg;
    type Out = Option<u32>;

    /// Batched admission: fill every lazy bound ([`DUB_PENDING`]) in the
    /// admitted batch with one blocked-kernel sweep over the padded hub
    /// tables — the amortization the per-query `dub_for` probe cannot
    /// get. Queries submitted with an explicit bound pass through
    /// untouched, so mixed batches work.
    fn admit_batch(&self, batch: &mut [Hub2QueryContent]) {
        let lazy: Vec<usize> = batch
            .iter()
            .enumerate()
            .filter(|(_, q)| q.2 == DUB_PENDING)
            .map(|(i, _)| i)
            .collect();
        if lazy.is_empty() {
            return;
        }
        let pairs: Vec<PpspQuery> = lazy.iter().map(|&i| (batch[i].0, batch[i].1)).collect();
        // c_pad = the rowmin kernel's row-tile, so padded chunks tile
        // evenly; k_pad = k (the CPU kernels auto-shrink their tiles).
        let dubs = self
            .idx
            .dub_for(&pairs, &BlockedMinPlus, rowmin::RM_TILE.0, self.idx.k());
        for (&i, d) in lazy.iter().zip(dubs) {
            batch[i].2 = d;
        }
    }

    /// Whale classification for the admission planner: a query whose
    /// index upper bound `d_ub` is at or above [`HEAVY_DUB_THRESHOLD`]
    /// (including [`UNREACHED`], where the index proves nothing and the
    /// BiBFS has no cutoff) is expected to grind for many supersteps.
    /// Evaluated at submission, BEFORE [`QueryApp::admit_batch`] — so a
    /// [`lazy_query`] still carries [`DUB_PENDING`] here and classifies
    /// light: callers who want whales routed to the reserved slice
    /// should resolve `d_ub` at the front end ([`Hub2Index::dub_for`])
    /// and submit explicit bounds, which is the serving hot path anyway.
    fn is_heavy(&self, q: &Hub2QueryContent) -> bool {
        q.2 != DUB_PENDING && q.2 >= HEAVY_DUB_THRESHOLD
    }

    fn init_activate(&self, q: &Hub2QueryContent) -> Vec<VertexId> {
        debug_assert_ne!(q.2, DUB_PENDING, "admit_batch must fill lazy d_ub");
        if q.0 == q.1 {
            vec![q.0]
        } else {
            vec![q.0, q.1]
        }
    }

    fn init_value(&self, q: &Hub2QueryContent, v: VertexId) -> BiState {
        BiState {
            ds: if v == q.0 { 0 } else { UNREACHED },
            dt: if v == q.1 { 0 } else { UNREACHED },
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut BiState) {
        let step = ctx.superstep();
        let (s, t, _dub) = *ctx.query();
        if step == 1 {
            if s == t {
                ctx.aggregate(|_, a| a.best = 0);
                ctx.force_terminate();
                ctx.vote_halt();
                return;
            }
            // s / t broadcast even if they are hubs (the hub-skip rule
            // applies to *interior* vertices only).
            if v == s {
                self.broadcast(ctx, v, FWD);
            }
            if v == t {
                self.broadcast(ctx, v, BWD);
            }
            ctx.vote_halt();
            return;
        }
        let mut mask = 0u8;
        for &m in ctx.msgs() {
            mask |= m;
        }
        let newly_fwd = mask & FWD != 0 && st.ds == UNREACHED;
        let newly_bwd = mask & BWD != 0 && st.dt == UNREACHED;
        if newly_fwd {
            st.ds = (step - 1) as u32;
        }
        if newly_bwd {
            st.dt = (step - 1) as u32;
        }
        // Interior hubs absorb the wavefront: any s->..->h->..->t path is
        // already covered by d_ub, so hubs never propagate.
        if self.idx.is_hub(v) && v != s && v != t {
            ctx.vote_halt();
            return;
        }
        if st.ds != UNREACHED && st.dt != UNREACHED && (newly_fwd || newly_bwd) {
            let sum = st.ds.saturating_add(st.dt);
            ctx.aggregate(|_, a| a.best = a.best.min(sum));
            ctx.force_terminate();
            ctx.vote_halt();
            return;
        }
        if newly_fwd {
            self.broadcast(ctx, v, FWD);
        }
        if newly_bwd {
            self.broadcast(ctx, v, BWD);
        }
        ctx.vote_halt();
    }

    fn combine(&self, into: &mut u8, from: &u8) -> bool {
        *into |= *from;
        true
    }

    fn agg_merge(&self, into: &mut BiAgg, from: &BiAgg) {
        into.best = into.best.min(from.best);
        into.fwd_sent += from.fwd_sent;
        into.bwd_sent += from.bwd_sent;
    }

    fn master_step(
        &self,
        q: &Hub2QueryContent,
        step: u64,
        prev: &BiAgg,
        agg: &mut BiAgg,
    ) -> MasterAction {
        let dub = q.2;
        agg.best = agg.best.min(prev.best);
        if agg.best != UNREACHED {
            return MasterAction::Terminate;
        }
        // Cutoff: a non-hub meeting at superstep i or later has sum
        // >= 2i - 1 >= d_ub, so d(s,t) = d_ub (paper §5.1.2).
        if dub != UNREACHED && step >= 1 + (dub as u64) / 2 {
            return MasterAction::Terminate;
        }
        if step >= 1 && (agg.fwd_sent == 0 || agg.bwd_sent == 0) {
            return MasterAction::Terminate;
        }
        agg.fwd_sent = 0;
        agg.bwd_sent = 0;
        MasterAction::Continue
    }

    fn finish(
        &self,
        q: &Hub2QueryContent,
        _touched: &mut dyn Iterator<Item = (VertexId, &BiState)>,
        agg: &BiAgg,
    ) -> Option<u32> {
        let d = q.2.min(agg.best);
        (d != UNREACHED).then_some(d)
    }

    fn msg_bytes(&self) -> usize {
        1
    }
}

// ---------------------------------------------------------------------------
// Streaming mutations: incremental label maintenance + the serving app.
// ---------------------------------------------------------------------------

/// Serial level-synchronous replay of one [`HubBfs`] job over a
/// [`VersionedGraph`] at a fixed epoch. Reproduces the engine app's
/// semantics exactly: `d(v) = superstep - 1`; `pre(v)` is the OR over all
/// shortest-path predecessors `u` of the message `u` relays, where the
/// root sends FALSE at step 1 and every other vertex relays
/// `is_hub(u) || pre(u)`. Reads go through the overlay accessors, so no
/// snapshot CSR is ever materialized — that is the whole point of
/// incremental maintenance.
fn hub_bfs_at(
    vg: &VersionedGraph,
    hub_rank: &FxHashMap<VertexId, u16>,
    pass: Pass,
    h: VertexId,
    e: Epoch,
) -> (Vec<u32>, Vec<bool>) {
    let n = vg.num_vertices_at(e);
    let mut dist = vec![UNREACHED; n];
    let mut pre = vec![false; n];
    if (h as usize) >= n {
        return (dist, pre);
    }
    dist[h as usize] = 0;
    let mut frontier = vec![h];
    let mut level = 1u32;
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &u in &frontier {
            let msg = u != h && (hub_rank.contains_key(&u) || pre[u as usize]);
            let nbrs = match pass {
                Pass::Forward => vg.out_at(u, e),
                Pass::Backward => vg.in_at(u, e),
            };
            for &v in nbrs.iter() {
                let dv = &mut dist[v as usize];
                if *dv == UNREACHED {
                    *dv = level;
                    pre[v as usize] |= msg;
                    next.push(v);
                } else if *dv == level {
                    // Another shortest-path predecessor: OR, exactly like
                    // the engine app's message combiner.
                    pre[v as usize] |= msg;
                }
            }
        }
        frontier = next;
        level += 1;
    }
    (dist, pre)
}

/// Incremental maintenance of a [`Hub2Index`] under streaming mutations.
///
/// The hub set is **frozen** at index-build time: degree ranks drift as
/// edges come and go, but re-picking hubs would invalidate every label at
/// once — the maintainer instead keeps the original hubs and repairs
/// their BFS trees. It caches each rank's full `(dist, pre)` rows (the
/// per-rank output of [`HubBfs`]); on a mutation batch it decides per
/// rank whether the batch can possibly change that rank's tree
/// (*affected-hub detection*, evaluated against the pre-batch rows):
///
/// * `AddEdge(u, v)` affects a forward rank iff
///   `d(h, u) + 1 <= d(h, v)` — strictly smaller shortens distances,
///   equal adds a shortest-path predecessor and may flip `pre(v)`;
/// * `DeleteEdge(u, v)` affects it iff `d(h, u) + 1 == d(h, v)` with both
///   finite — only tight arcs lie on shortest paths;
/// * `DeleteVertex(v)` affects it iff `d(h, v)` is finite;
/// * `AddVertex` affects nothing (the new slot is isolated);
/// * backward ranks mirror the criteria with the arc reversed.
///
/// (Soundness: walk any post-batch shortest path; if no added arc on it
/// triggers the `<=` test, induction over the old distances bounds the
/// old distance by the new length — so a change implies a trigger.)
/// Affected ranks rerun one serial BFS each over the overlay accessors
/// and patch their `hub_dist` row/column and their label entries in
/// place; unaffected ranks are untouched. With full (untruncated) BFS
/// distances the repaired table is already closed, so no min-plus
/// re-closure is needed. The correctness baseline is
/// [`Hub2Indexer::build_with_hubs`] over a materialized snapshot with the
/// same frozen hubs — the parity tests below hold the two bit-identical.
pub struct Hub2Maintainer {
    undirected: bool,
    hubs: Vec<VertexId>,
    hub_rank: FxHashMap<VertexId, u16>,
    /// Per-rank forward BFS rows: `dist_fwd[i][v] = d(h_i, v)`.
    dist_fwd: Vec<Vec<u32>>,
    pre_fwd: Vec<Vec<bool>>,
    /// Backward side (`d(v, h_i)`); empty when undirected.
    dist_bwd: Vec<Vec<u32>>,
    pre_bwd: Vec<Vec<bool>>,
}

impl Hub2Maintainer {
    /// Seed the maintainer from a freshly built index (full BFS only:
    /// truncated-radius indexes under-represent the trees the maintainer
    /// repairs). Runs one serial BFS per rank and pass at the current
    /// epoch of `vg`.
    pub fn new(vg: &VersionedGraph, idx: &Hub2Index, undirected: bool) -> Self {
        let e = vg.epoch();
        let k = idx.k();
        let mut m = Self {
            undirected,
            hubs: idx.hubs.clone(),
            hub_rank: idx.hub_rank.clone(),
            dist_fwd: Vec::with_capacity(k),
            pre_fwd: Vec::with_capacity(k),
            dist_bwd: Vec::new(),
            pre_bwd: Vec::new(),
        };
        for i in 0..k {
            let (d, p) = hub_bfs_at(vg, &m.hub_rank, Pass::Forward, m.hubs[i], e);
            m.dist_fwd.push(d);
            m.pre_fwd.push(p);
            if !undirected {
                let (d, p) = hub_bfs_at(vg, &m.hub_rank, Pass::Backward, m.hubs[i], e);
                m.dist_bwd.push(d);
                m.pre_bwd.push(p);
            }
        }
        m
    }

    /// Number of hubs under maintenance.
    pub fn k(&self) -> usize {
        self.hubs.len()
    }

    /// Strip rank `rank`'s entry from every label row, then re-insert
    /// `(rank, d)` (rank-sorted, matching build order) for every live
    /// non-hub vertex with a finite, un-shadowed distance.
    fn patch_labels(
        labels: &mut [Vec<(u16, u32)>],
        hub_rank: &FxHashMap<VertexId, u16>,
        rank: u16,
        dist: &[u32],
        pre: &[bool],
    ) {
        for (v, row) in labels.iter_mut().enumerate() {
            if let Some(p) = row.iter().position(|&(r, _)| r == rank) {
                row.remove(p);
            }
            let d = dist.get(v).copied().unwrap_or(UNREACHED);
            if d != UNREACHED && !pre[v] && !hub_rank.contains_key(&(v as VertexId)) {
                let p = row.partition_point(|&(r, _)| r < rank);
                row.insert(p, (rank, d));
            }
        }
    }

    /// Fold one applied batch into the index. `vg` must already be at the
    /// post-batch epoch (the batch this call repairs is the one that
    /// produced `vg.epoch()`). For undirected-stored graphs the batch
    /// must contain both arcs of every logical edge, like the builder
    /// does. Returns the number of BFS recomputations performed — the
    /// quantity the incremental path saves versus `2k` (or `k`
    /// undirected) for a full rebuild.
    pub fn refresh(
        &mut self,
        vg: &VersionedGraph,
        idx: &mut Hub2Index,
        batch: &MutationBatch,
    ) -> usize {
        let e = vg.epoch();
        let k = self.hubs.len();
        let n = vg.num_vertices_at(e);
        let d_of = |row: &[u32], v: VertexId| row.get(v as usize).copied().unwrap_or(UNREACHED);
        let mut aff_fwd = vec![false; k];
        let mut aff_bwd = vec![false; k];
        let mut deleted: Vec<VertexId> = Vec::new();
        for m in &batch.muts {
            match *m {
                Mutation::AddEdge { src, dst, .. } => {
                    for i in 0..k {
                        let (du, dv) = (d_of(&self.dist_fwd[i], src), d_of(&self.dist_fwd[i], dst));
                        aff_fwd[i] |= du != UNREACHED && du + 1 <= dv;
                        if !self.undirected {
                            let (dv, du) =
                                (d_of(&self.dist_bwd[i], dst), d_of(&self.dist_bwd[i], src));
                            aff_bwd[i] |= dv != UNREACHED && dv + 1 <= du;
                        }
                    }
                }
                Mutation::DeleteEdge { src, dst } => {
                    for i in 0..k {
                        let (du, dv) = (d_of(&self.dist_fwd[i], src), d_of(&self.dist_fwd[i], dst));
                        aff_fwd[i] |= du != UNREACHED && dv != UNREACHED && du + 1 == dv;
                        if !self.undirected {
                            let (dv, du) =
                                (d_of(&self.dist_bwd[i], dst), d_of(&self.dist_bwd[i], src));
                            aff_bwd[i] |= dv != UNREACHED && du != UNREACHED && dv + 1 == du;
                        }
                    }
                }
                Mutation::AddVertex => {}
                Mutation::DeleteVertex { v } => {
                    deleted.push(v);
                    for i in 0..k {
                        aff_fwd[i] |= d_of(&self.dist_fwd[i], v) != UNREACHED;
                        if !self.undirected {
                            aff_bwd[i] |= d_of(&self.dist_bwd[i], v) != UNREACHED;
                        }
                    }
                }
            }
        }
        // Grow per-vertex rows for slots added by this batch (for
        // unaffected ranks too: every row tracks the current id space).
        idx.label_out.resize(n, Vec::new());
        idx.label_in.resize(n, Vec::new());
        for i in 0..k {
            self.dist_fwd[i].resize(n, UNREACHED);
            self.pre_fwd[i].resize(n, false);
            if !self.undirected {
                self.dist_bwd[i].resize(n, UNREACHED);
                self.pre_bwd[i].resize(n, false);
            }
        }
        let mut recomputed = 0;
        for i in 0..k {
            if aff_fwd[i] {
                recomputed += 1;
                let (d, p) = hub_bfs_at(vg, &self.hub_rank, Pass::Forward, self.hubs[i], e);
                for j in 0..k {
                    idx.hub_dist[i * k + j] = to_f(d_of(&d, self.hubs[j]));
                }
                Self::patch_labels(&mut idx.label_out, &self.hub_rank, i as u16, &d, &p);
                if self.undirected {
                    Self::patch_labels(&mut idx.label_in, &self.hub_rank, i as u16, &d, &p);
                }
                self.dist_fwd[i] = d;
                self.pre_fwd[i] = p;
            }
            if !self.undirected && aff_bwd[i] {
                recomputed += 1;
                let (d, p) = hub_bfs_at(vg, &self.hub_rank, Pass::Backward, self.hubs[i], e);
                for j in 0..k {
                    idx.hub_dist[j * k + i] = to_f(d_of(&d, self.hubs[j]));
                }
                Self::patch_labels(&mut idx.label_in, &self.hub_rank, i as u16, &d, &p);
                self.dist_bwd[i] = d;
                self.pre_bwd[i] = p;
            }
        }
        // Deleted slots read as isolated from `e` on: no labels at all.
        // (Every rank that could have labeled them is affected and was
        // just repaired; the explicit clear also covers their entries.)
        for v in deleted {
            idx.label_out[v as usize].clear();
            idx.label_in[v as usize].clear();
        }
        recomputed
    }
}

/// Query content of the serving app: a [`Hub2QueryContent`] plus the
/// graph epoch pinned at admission (stamped by [`QueryApp::pin_epoch`] —
/// part of the frozen query content, so the whole lifetime of the query
/// reads one consistent version).
pub type Hub2ServeQuery = (VertexId, VertexId, u32, Epoch);

/// A lazily-bounded serving query: `d_ub` is filled by the admission
/// hook's batched kernel sweep and the epoch is stamped at admission.
/// This is the sanctioned submission path under mutations — an
/// *explicitly* bounded query computed against an older epoch could
/// carry a `d_ub` a later delete has invalidated; the lazy path computes
/// the bound at admission, against the index at the very epoch the query
/// pins, so it is always valid for the version the query reads.
#[inline]
pub fn lazy_serve_query(s: VertexId, t: VertexId) -> Hub2ServeQuery {
    (s, t, DUB_PENDING, 0)
}

/// The always-on serving variant of [`Hub2Query`]: owns a
/// [`VersionedGraph`] plus the index and its maintainer, and accepts
/// streaming mutations through the [`QueryApp`] mutation hooks. Each
/// query reads the version pinned at its admission
/// ([`VersionedGraph::out_at`] / [`VersionedGraph::in_at`] at the
/// stamped epoch); the hub set is frozen, so `is_hub` — the only index
/// state `compute` consults — is epoch-independent.
pub struct Hub2Serve {
    vg: VersionedGraph,
    idx: Hub2Index,
    maint: Hub2Maintainer,
}

impl Hub2Serve {
    /// Build the index over `g` (full BFS — the maintainer requires
    /// untruncated hub distances) and wrap `g` for versioned serving.
    pub fn build(mut g: Graph, indexer: &Hub2Indexer, cluster: Cluster, mp: &dyn MinPlus) -> Self {
        assert!(
            indexer.radius.is_none(),
            "Hub2Maintainer requires full-BFS indexing (radius = None)"
        );
        g.ensure_in_edges();
        let (idx, _) = indexer.build(&g, cluster, mp);
        let vg = VersionedGraph::new(g);
        let maint = Hub2Maintainer::new(&vg, &idx, indexer.undirected);
        Self { vg, idx, maint }
    }

    /// The versioned graph being served.
    pub fn graph(&self) -> &VersionedGraph {
        &self.vg
    }

    /// The maintained index (current-epoch view).
    pub fn index(&self) -> &Hub2Index {
        &self.idx
    }

    fn broadcast(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, dir: u8, e: Epoch) {
        if dir == FWD {
            let nbrs = self.vg.out_at(v, e);
            for &u in nbrs.iter() {
                ctx.send(u, FWD);
            }
            let n = nbrs.len() as u64;
            ctx.aggregate(|_, a| a.fwd_sent += n);
        } else {
            let nbrs = self.vg.in_at(v, e);
            for &u in nbrs.iter() {
                ctx.send(u, BWD);
            }
            let n = nbrs.len() as u64;
            ctx.aggregate(|_, a| a.bwd_sent += n);
        }
    }
}

impl QueryApp for Hub2Serve {
    type Query = Hub2ServeQuery;
    type VQ = BiState;
    type Msg = u8;
    type Agg = BiAgg;
    type Out = Option<u32>;

    fn supports_mutations(&self) -> bool {
        true
    }

    fn apply_mutations(&mut self, batch: &MutationBatch) -> MutationApplied {
        let applied = self.vg.apply(batch);
        self.maint.refresh(&self.vg, &mut self.idx, batch);
        applied
    }

    fn pin_epoch(&self, batch: &mut [Hub2ServeQuery], epoch: Epoch) {
        for q in batch {
            q.3 = epoch;
        }
    }

    fn retire_epochs(&mut self, oldest: Epoch) {
        self.vg.retire(oldest);
    }

    /// Same batched sweep as [`Hub2Query::admit_batch`]. Runs after
    /// [`QueryApp::pin_epoch`] in the same admission round, and mutations
    /// land before admission — so the bound is computed against the index
    /// at exactly the epoch the query pins.
    fn admit_batch(&self, batch: &mut [Hub2ServeQuery]) {
        let lazy: Vec<usize> = batch
            .iter()
            .enumerate()
            .filter(|(_, q)| q.2 == DUB_PENDING)
            .map(|(i, _)| i)
            .collect();
        if lazy.is_empty() {
            return;
        }
        let pairs: Vec<PpspQuery> = lazy.iter().map(|&i| (batch[i].0, batch[i].1)).collect();
        let dubs = self
            .idx
            .dub_for(&pairs, &BlockedMinPlus, rowmin::RM_TILE.0, self.idx.k());
        for (&i, d) in lazy.iter().zip(dubs) {
            batch[i].2 = d;
        }
    }

    fn is_heavy(&self, q: &Hub2ServeQuery) -> bool {
        q.2 != DUB_PENDING && q.2 >= HEAVY_DUB_THRESHOLD
    }

    fn init_activate(&self, q: &Hub2ServeQuery) -> Vec<VertexId> {
        debug_assert_ne!(q.2, DUB_PENDING, "admit_batch must fill lazy d_ub");
        if q.0 == q.1 {
            vec![q.0]
        } else {
            vec![q.0, q.1]
        }
    }

    fn init_value(&self, q: &Hub2ServeQuery, v: VertexId) -> BiState {
        BiState {
            ds: if v == q.0 { 0 } else { UNREACHED },
            dt: if v == q.1 { 0 } else { UNREACHED },
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut BiState) {
        let step = ctx.superstep();
        let (s, t, _dub, e) = *ctx.query();
        if step == 1 {
            if s == t {
                ctx.aggregate(|_, a| a.best = 0);
                ctx.force_terminate();
                ctx.vote_halt();
                return;
            }
            if v == s {
                self.broadcast(ctx, v, FWD, e);
            }
            if v == t {
                self.broadcast(ctx, v, BWD, e);
            }
            ctx.vote_halt();
            return;
        }
        let mut mask = 0u8;
        for &m in ctx.msgs() {
            mask |= m;
        }
        let newly_fwd = mask & FWD != 0 && st.ds == UNREACHED;
        let newly_bwd = mask & BWD != 0 && st.dt == UNREACHED;
        if newly_fwd {
            st.ds = (step - 1) as u32;
        }
        if newly_bwd {
            st.dt = (step - 1) as u32;
        }
        if self.idx.is_hub(v) && v != s && v != t {
            ctx.vote_halt();
            return;
        }
        if st.ds != UNREACHED && st.dt != UNREACHED && (newly_fwd || newly_bwd) {
            let sum = st.ds.saturating_add(st.dt);
            ctx.aggregate(|_, a| a.best = a.best.min(sum));
            ctx.force_terminate();
            ctx.vote_halt();
            return;
        }
        if newly_fwd {
            self.broadcast(ctx, v, FWD, e);
        }
        if newly_bwd {
            self.broadcast(ctx, v, BWD, e);
        }
        ctx.vote_halt();
    }

    fn combine(&self, into: &mut u8, from: &u8) -> bool {
        *into |= *from;
        true
    }

    fn agg_merge(&self, into: &mut BiAgg, from: &BiAgg) {
        into.best = into.best.min(from.best);
        into.fwd_sent += from.fwd_sent;
        into.bwd_sent += from.bwd_sent;
    }

    fn master_step(
        &self,
        q: &Hub2ServeQuery,
        step: u64,
        prev: &BiAgg,
        agg: &mut BiAgg,
    ) -> MasterAction {
        let dub = q.2;
        agg.best = agg.best.min(prev.best);
        if agg.best != UNREACHED {
            return MasterAction::Terminate;
        }
        if dub != UNREACHED && step >= 1 + (dub as u64) / 2 {
            return MasterAction::Terminate;
        }
        if step >= 1 && (agg.fwd_sent == 0 || agg.bwd_sent == 0) {
            return MasterAction::Terminate;
        }
        agg.fwd_sent = 0;
        agg.bwd_sent = 0;
        MasterAction::Continue
    }

    fn finish(
        &self,
        q: &Hub2ServeQuery,
        _touched: &mut dyn Iterator<Item = (VertexId, &BiState)>,
        agg: &BiAgg,
    ) -> Option<u32> {
        let d = q.2.min(agg.best);
        (d != UNREACHED).then_some(d)
    }

    fn msg_bytes(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::super::oracle;
    use super::*;
    use crate::graph::gen;

    fn build_index(g: &Graph, k: usize, undirected: bool) -> Hub2Index {
        Hub2Indexer::new(k)
            .undirected(undirected)
            .build(g, Cluster::new(4), &RustMinPlus)
            .0
    }

    fn hub2_query(g: &Graph, idx: &Hub2Index, s: VertexId, t: VertexId) -> Option<u32> {
        let dub = idx.dub_for(&[(s, t)], &RustMinPlus, 1, idx.k())[0];
        let mut eng = Engine::new(Hub2Query::new(g, idx), Cluster::new(4), g.num_vertices());
        eng.run_one((s, t, dub)).out
    }

    #[test]
    fn hub2_matches_oracle_directed() {
        let mut g = gen::twitter_like(400, 5, 31);
        g.ensure_in_edges();
        let idx = build_index(&g, 16, false);
        for (s, t) in gen::random_pairs(400, 20, 32) {
            let want = oracle::bfs_dist(&g, s, t);
            let got = hub2_query(&g, &idx, s, t);
            assert_eq!(got, (want != UNREACHED).then_some(want), "({s},{t})");
        }
    }

    #[test]
    fn hub2_matches_oracle_undirected_multi_cc() {
        let mut g = gen::btc_like(500, 50, 4, 33);
        g.ensure_in_edges();
        let idx = build_index(&g, 12, true);
        for (s, t) in gen::random_pairs(500, 20, 34) {
            let want = oracle::bfs_dist(&g, s, t);
            let got = hub2_query(&g, &idx, s, t);
            assert_eq!(got, (want != UNREACHED).then_some(want), "({s},{t})");
        }
    }

    #[test]
    fn heavy_classification_follows_dub_threshold() {
        let mut g = gen::twitter_like(200, 5, 37);
        g.ensure_in_edges();
        let idx = build_index(&g, 8, false);
        let app = Hub2Query::new(&g, &idx);
        // Provably cheap (tight index cutoff): light.
        assert!(!app.is_heavy(&(0, 1, 2)));
        assert!(!app.is_heavy(&(0, 1, HEAVY_DUB_THRESHOLD - 1)));
        // At/above the threshold, including "index proves nothing": heavy.
        assert!(app.is_heavy(&(0, 1, HEAVY_DUB_THRESHOLD)));
        assert!(app.is_heavy(&(0, 1, UNREACHED)));
        // Lazy bound not yet filled: cost unknown, classifies light
        // (is_heavy runs at submission, before admit_batch).
        assert!(!app.is_heavy(&lazy_query(0, 1)));
    }

    #[test]
    fn hub_to_hub_queries() {
        let mut g = gen::twitter_like(300, 5, 35);
        g.ensure_in_edges();
        let idx = build_index(&g, 8, false);
        let h0 = idx.hubs[0];
        let h1 = idx.hubs[1];
        let want = oracle::bfs_dist(&g, h0, h1);
        assert_eq!(
            hub2_query(&g, &idx, h0, h1),
            (want != UNREACHED).then_some(want)
        );
    }

    #[test]
    fn truncated_indexing_never_underestimates() {
        let mut g = gen::twitter_like(300, 6, 36);
        g.ensure_in_edges();
        let full = build_index(&g, 8, false);
        let trunc = Hub2Indexer::new(8)
            .radius(Some(2))
            .build(&g, Cluster::new(4), &RustMinPlus)
            .0;
        for i in 0..full.k() {
            for j in 0..full.k() {
                let f = full.hub_dist[i * full.k() + j];
                let t = trunc.hub_dist[i * trunc.k() + j];
                assert!(
                    t >= f,
                    "truncated+closure must never underestimate ({i},{j}): {t} < {f}"
                );
            }
        }
    }

    #[test]
    fn dub_is_upper_bound() {
        let mut g = gen::twitter_like(300, 5, 37);
        g.ensure_in_edges();
        let idx = build_index(&g, 16, false);
        for (s, t) in gen::random_pairs(300, 20, 38) {
            let want = oracle::bfs_dist(&g, s, t);
            let dub = idx.dub_for(&[(s, t)], &RustMinPlus, 1, idx.k())[0];
            assert!(dub >= want, "d_ub {dub} < true distance {want} ({s},{t})");
        }
    }

    /// Index-construction check: the k×k hub distance table produced by
    /// the |H| superstep-shared BFS jobs must equal pairwise oracle BFS
    /// distances exactly (full indexing, no truncation — the closure must
    /// then be an idempotent no-op on an already-exact table).
    #[test]
    fn hub_dist_matches_oracle_pairwise() {
        let mut g = gen::twitter_like(400, 5, 41);
        g.ensure_in_edges();
        let idx = build_index(&g, 12, false);
        let k = idx.k();
        for i in 0..k {
            for j in 0..k {
                let want = oracle::bfs_dist(&g, idx.hubs[i], idx.hubs[j]);
                let got = from_f(idx.hub_dist[i * k + j]);
                assert_eq!(
                    got, want,
                    "D_H[{i},{j}] = d({}, {})",
                    idx.hubs[i], idx.hubs[j]
                );
            }
        }
    }

    /// Index-construction check: every core-hub label distance must be the
    /// true shortest-path distance — `L_out(v)` holds `d(h, v)` (forward
    /// pass) and `L_in(v)` holds `d(v, h)` (backward pass). Labels with a
    /// wrong distance would silently corrupt every `d_ub` they feed.
    #[test]
    fn core_hub_labels_match_oracle_distances() {
        let mut g = gen::twitter_like(400, 5, 42);
        g.ensure_in_edges();
        let idx = build_index(&g, 12, false);
        for v in 0..g.num_vertices() as VertexId {
            if idx.is_hub(v) {
                continue;
            }
            for &(h, d) in &idx.label_out[v as usize] {
                let want = oracle::bfs_dist(&g, idx.hubs[h as usize], v);
                assert_eq!(d, want, "L_out({v}) hub {h}");
            }
            for &(h, d) in &idx.label_in[v as usize] {
                let want = oracle::bfs_dist(&g, v, idx.hubs[h as usize]);
                assert_eq!(d, want, "L_in({v}) hub {h}");
            }
        }
    }

    /// The BiBFS cutoff contract on a random graph: with `d_ub` in hand
    /// the restricted BiBFS must (a) still return the oracle distance and
    /// (b) stop within `1 + floor(d_ub / 2)` supersteps — the §5.1.2
    /// argument that a non-hub meeting at superstep i has path length
    /// >= 2i - 1 >= d_ub, so searching further is pointless.
    #[test]
    fn bibfs_cutoff_matches_oracle() {
        let mut g = gen::twitter_like(500, 5, 43);
        g.ensure_in_edges();
        let idx = build_index(&g, 16, false);
        for (s, t) in gen::random_pairs(500, 25, 44) {
            let dub = idx.dub_for(&[(s, t)], &RustMinPlus, 1, idx.k())[0];
            let mut eng = Engine::new(Hub2Query::new(&g, &idx), Cluster::new(4), 500);
            let r = eng.run_one((s, t, dub));
            let want = oracle::bfs_dist(&g, s, t);
            assert_eq!(r.out, (want != UNREACHED).then_some(want), "({s},{t})");
            if dub != UNREACHED {
                assert!(
                    r.stats.supersteps <= 1 + dub as u64 / 2,
                    "({s},{t}): {} supersteps past the 1 + {dub}/2 cutoff",
                    r.stats.supersteps
                );
            }
        }
    }

    /// The blocked-kernel evaluator must agree bit-exactly with the naive
    /// oracle on hub-shaped tables (hop counts + INF): closure and the
    /// two-stage batched upper bound alike. This is the CPU analog of the
    /// Pallas-vs-reference parity tests in python/compile.
    #[test]
    fn blocked_minplus_matches_rust_oracle() {
        let mut seed = 0x5EEDu32;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 17;
            seed ^= seed << 5;
            seed
        };
        let mut hop = move || {
            let r = next();
            if r % 4 == 0 {
                F_INF
            } else {
                (r % 30) as f32
            }
        };
        for &(c, k) in &[(1usize, 4usize), (5, 8), (9, 16)] {
            let mut d: Vec<f32> = (0..k * k).map(|_| hop()).collect();
            for i in 0..k {
                d[i * k + i] = 0.0;
            }
            let mut d_blocked = d.clone();
            BlockedMinPlus.closure(&mut d_blocked, k);
            RustMinPlus.closure(&mut d, k);
            assert_eq!(d_blocked, d, "closure ({k}x{k})");
            let s: Vec<f32> = (0..c * k).map(|_| hop()).collect();
            let t: Vec<f32> = (0..c * k).map(|_| hop()).collect();
            assert_eq!(
                BlockedMinPlus.dub_batch(&s, &d, &t, c, k),
                RustMinPlus.dub_batch(&s, &d, &t, c, k),
                "dub_batch ({c}x{k})"
            );
        }
    }

    /// Lazy submission ([`lazy_query`]) must be indistinguishable from the
    /// explicit path: the admission hook's batched kernel sweep fills the
    /// same d_ub `dub_for` computes per query, so outputs and superstep
    /// counts match — and both match the BFS oracle.
    #[test]
    fn lazy_dub_queries_match_explicit() {
        let mut g = gen::twitter_like(400, 5, 45);
        g.ensure_in_edges();
        let idx = build_index(&g, 16, false);
        for (s, t) in gen::random_pairs(400, 15, 46) {
            let explicit = hub2_query(&g, &idx, s, t);
            let mut eng = Engine::new(Hub2Query::new(&g, &idx), Cluster::new(4), 400);
            let lazy = eng.run_one(lazy_query(s, t));
            assert_eq!(lazy.out, explicit, "lazy vs explicit ({s},{t})");
            let want = oracle::bfs_dist(&g, s, t);
            assert_eq!(lazy.out, (want != UNREACHED).then_some(want), "({s},{t})");
        }
    }

    /// A whole batch of lazy queries superstep-shared under one capacity
    /// still gets every bound filled (the hook runs per admission round,
    /// not just for run_one's singleton batch).
    #[test]
    fn lazy_dub_fills_whole_admitted_batches() {
        let mut g = gen::twitter_like(400, 5, 47);
        g.ensure_in_edges();
        let idx = build_index(&g, 12, false);
        let pairs = gen::random_pairs(400, 12, 48);
        let mut eng =
            Engine::new(Hub2Query::new(&g, &idx), Cluster::new(4), 400).capacity(4);
        let qids: Vec<_> = pairs.iter().map(|&(s, t)| eng.submit(lazy_query(s, t))).collect();
        eng.run_until_idle();
        for (&(s, t), &qid) in pairs.iter().zip(&qids) {
            let got = eng
                .results()
                .iter()
                .find(|r| r.qid == qid)
                .expect("query completed")
                .out;
            let want = oracle::bfs_dist(&g, s, t);
            assert_eq!(got, (want != UNREACHED).then_some(want), "({s},{t})");
        }
    }

    fn xorshift(seed: &mut u32) -> u32 {
        *seed ^= *seed << 13;
        *seed ^= *seed >> 17;
        *seed ^= *seed << 5;
        *seed
    }

    /// The incremental maintainer must stay bit-identical to a full
    /// rebuild over the same frozen hubs, batch after batch — edge adds,
    /// edge deletes, a vertex add wired into the graph, and a vertex
    /// delete (directed graph, both BFS passes).
    #[test]
    fn maintainer_matches_frozen_hub_rebuild_directed() {
        let mut g = gen::twitter_like(300, 5, 51);
        g.ensure_in_edges();
        let indexer = Hub2Indexer::new(10);
        let (mut idx, _) = indexer.build(&g, Cluster::new(4), &RustMinPlus);
        let hubs = idx.hubs.clone();
        let mut vg = VersionedGraph::new(g);
        let mut maint = Hub2Maintainer::new(&vg, &idx, false);
        let mut seed = 0x9E37_79B9u32;
        for round in 0..6 {
            let e = vg.epoch();
            let n = vg.num_vertices_at(e) as VertexId;
            let mut batch = MutationBatch::new();
            if round == 4 {
                let v = loop {
                    let v = xorshift(&mut seed) % n;
                    if vg.is_live_at(v, e) && !idx.is_hub(v) {
                        break v;
                    }
                };
                batch.delete_vertex(v);
            } else {
                for _ in 0..3 {
                    let (u, v) = loop {
                        let u = xorshift(&mut seed) % n;
                        let v = xorshift(&mut seed) % n;
                        if u != v && vg.is_live_at(u, e) && vg.is_live_at(v, e) {
                            break (u, v);
                        }
                    };
                    batch.add_edge(u, v);
                }
                for _ in 0..2 {
                    // Deletes are drawn from arcs that actually exist.
                    let (u, v) = loop {
                        let u = xorshift(&mut seed) % n;
                        let nb = vg.out_at(u, e);
                        if !nb.is_empty() {
                            let v = nb[xorshift(&mut seed) as usize % nb.len()];
                            break (u, v);
                        }
                    };
                    batch.delete_edge(u, v);
                }
                if round == 2 {
                    let x = loop {
                        let x = xorshift(&mut seed) % n;
                        if vg.is_live_at(x, e) {
                            break x;
                        }
                    };
                    batch.add_vertex().add_edge(n, x).add_edge(x, n);
                }
            }
            vg.apply(&batch);
            let recomputed = maint.refresh(&vg, &mut idx, &batch);
            assert!(recomputed <= 2 * maint.k(), "round {round}");
            let mut snap = vg.snapshot_at(vg.epoch());
            snap.ensure_in_edges();
            let (want, _) =
                indexer.build_with_hubs(&snap, hubs.clone(), Cluster::new(4), &RustMinPlus);
            assert_eq!(idx.hub_dist, want.hub_dist, "hub_dist round {round}");
            assert_eq!(idx.label_out, want.label_out, "label_out round {round}");
            assert_eq!(idx.label_in, want.label_in, "label_in round {round}");
        }
    }

    /// Undirected parity: batches carry both arcs of every logical edge
    /// (matching the undirected storage) and `L_in` must stay the mirror
    /// of `L_out` through every refresh.
    #[test]
    fn maintainer_matches_frozen_hub_rebuild_undirected() {
        let mut g = gen::btc_like(200, 20, 3, 52);
        g.ensure_in_edges();
        let indexer = Hub2Indexer::new(8).undirected(true);
        let (mut idx, _) = indexer.build(&g, Cluster::new(4), &RustMinPlus);
        let hubs = idx.hubs.clone();
        let mut vg = VersionedGraph::new(g);
        let mut maint = Hub2Maintainer::new(&vg, &idx, true);
        let mut seed = 0xB5EE_D101u32;
        for round in 0..5 {
            let e = vg.epoch();
            let n = vg.num_vertices_at(e) as VertexId;
            let mut batch = MutationBatch::new();
            if round == 3 {
                let v = loop {
                    let v = xorshift(&mut seed) % n;
                    if vg.is_live_at(v, e) && !idx.is_hub(v) {
                        break v;
                    }
                };
                batch.delete_vertex(v);
            } else {
                for _ in 0..2 {
                    let (u, v) = loop {
                        let u = xorshift(&mut seed) % n;
                        let v = xorshift(&mut seed) % n;
                        if u != v && vg.is_live_at(u, e) && vg.is_live_at(v, e) {
                            break (u, v);
                        }
                    };
                    batch.add_edge(u, v).add_edge(v, u);
                }
                let (u, v) = loop {
                    let u = xorshift(&mut seed) % n;
                    let nb = vg.out_at(u, e);
                    if !nb.is_empty() {
                        let v = nb[xorshift(&mut seed) as usize % nb.len()];
                        break (u, v);
                    }
                };
                batch.delete_edge(u, v).delete_edge(v, u);
            }
            vg.apply(&batch);
            maint.refresh(&vg, &mut idx, &batch);
            let mut snap = vg.snapshot_at(vg.epoch());
            snap.ensure_in_edges();
            let (want, _) =
                indexer.build_with_hubs(&snap, hubs.clone(), Cluster::new(4), &RustMinPlus);
            assert_eq!(idx.hub_dist, want.hub_dist, "hub_dist round {round}");
            assert_eq!(idx.label_out, want.label_out, "label_out round {round}");
            assert_eq!(idx.label_in, idx.label_out, "L_in mirrors L_out, round {round}");
        }
    }

    /// The pinned-d_ub regression: a query admitted at epoch 0 carries a
    /// d_ub computed against epoch 0's index; a delete that lands while
    /// it is in flight severs the very path behind that bound — but the
    /// query reads its pinned version and must still report the epoch-0
    /// distance. A query admitted after the delete sees the cut.
    #[test]
    fn pinned_query_is_isolated_from_later_deletes() {
        // Directed path 0 -> 1 -> ... -> 7 (d(0, 7) = 7).
        let mut b = crate::graph::GraphBuilder::new(8);
        for i in 0..7u32 {
            b.edge(i, i + 1);
        }
        let mut g = b.build();
        g.ensure_in_edges();
        let app = Hub2Serve::build(g, &Hub2Indexer::new(2), Cluster::new(4), &RustMinPlus);
        let mut eng = Engine::new(app, Cluster::new(4), 8);
        let qid = eng.try_submit(lazy_serve_query(0, 7), 0.0).unwrap();
        // One super-round: the query is admitted (pinning epoch 0, with a
        // d_ub priced against epoch 0) and runs superstep 1.
        assert!(eng.super_round());
        // Cut the path mid-flight. The batch applies at the next round
        // boundary, creating epoch 1 — invisible to the pinned query.
        let mut batch = MutationBatch::new();
        batch.delete_edge(3, 4);
        eng.try_mutate(batch, 0.0).unwrap();
        eng.run_until_idle();
        let r = eng.results().iter().find(|r| r.qid == qid).unwrap();
        assert_eq!(r.out, Some(7), "pinned query must answer at epoch 0");
        assert_eq!(r.stats.epoch, 0);
        assert_eq!(eng.metrics().epochs_applied, 1);
        assert!(eng.metrics().delta_bytes_peak > 0);
        // Idle with nothing pinned behind: the overlay compacted.
        assert_eq!(eng.metrics().oldest_pinned_epoch, 1);
        assert_eq!(eng.app().graph().base_epoch(), 1);
        // A fresh query pins epoch 1 and sees the severed path.
        let qid2 = eng.try_submit(lazy_serve_query(0, 7), eng.sim_time()).unwrap();
        eng.run_until_idle();
        let r2 = eng.results().iter().find(|r| r.qid == qid2).unwrap();
        assert_eq!(r2.out, None, "post-delete epoch has no 0 -> 7 path");
        assert_eq!(r2.stats.epoch, 1);
    }

    /// Mutations offered to an app without mutation support bounce back.
    #[test]
    fn try_mutate_rejects_immutable_apps() {
        let mut g = gen::twitter_like(100, 4, 53);
        g.ensure_in_edges();
        let idx = build_index(&g, 4, false);
        let mut eng = Engine::new(Hub2Query::new(&g, &idx), Cluster::new(4), 100);
        let mut batch = MutationBatch::new();
        batch.add_edge(0, 1);
        assert!(eng.try_mutate(batch, 0.0).is_err());
    }

    #[test]
    fn rust_minplus_closure_small() {
        // 0 ->(3) 1 ->(4) 2, expect d(0,2)=7 after closure.
        let k = 3;
        let mut d = vec![F_INF; k * k];
        d[0] = 0.0;
        d[4] = 0.0;
        d[8] = 0.0;
        d[1] = 3.0;
        d[5] = 4.0;
        RustMinPlus.closure(&mut d, k);
        assert_eq!(d[2], 7.0);
    }

    #[test]
    fn f_encoding_roundtrip() {
        assert_eq!(from_f(to_f(UNREACHED)), UNREACHED);
        assert_eq!(from_f(to_f(17)), 17);
        assert_eq!(from_f(F_INF + 100.0), UNREACHED);
    }

    #[test]
    fn access_rate_lower_with_index() {
        // The whole point of Hub^2: the touched set shrinks vs plain BiBFS.
        let mut g = gen::twitter_like(2_000, 8, 39);
        g.ensure_in_edges();
        let idx = build_index(&g, 32, false);
        let pairs = gen::random_pairs(2_000, 10, 40);
        let mut bibfs_touched = 0u64;
        let mut hub2_touched = 0u64;
        for &(s, t) in &pairs {
            let mut e1 = Engine::new(super::super::BiBfs::new(&g), Cluster::new(4), 2_000);
            bibfs_touched += e1.run_one((s, t)).stats.touched;
            let dub = idx.dub_for(&[(s, t)], &RustMinPlus, 1, idx.k())[0];
            let mut e2 = Engine::new(Hub2Query::new(&g, &idx), Cluster::new(4), 2_000);
            hub2_touched += e2.run_one((s, t, dub)).stats.touched;
        }
        assert!(
            hub2_touched < bibfs_touched,
            "hub2 {hub2_touched} !< bibfs {bibfs_touched}"
        );
    }
}
