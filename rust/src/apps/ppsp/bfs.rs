//! Plain BFS for PPSP queries (paper §5.1.1).
//!
//! `a_q(v)` is the current estimate of d(s, v); only `s` is activated
//! initially; a vertex visited for the first time at superstep `i` sets
//! d(s, v) = i - 1, broadcasts to its out-neighbors and halts. When the
//! BFS reaches `t`, `t` calls `force_terminate()`.

use super::{PpspQuery, UNREACHED};
use crate::graph::{Graph, VertexId};
use crate::vertex::{Ctx, QueryApp};

/// BFS PPSP application. V-data = the graph's out-adjacency.
pub struct Bfs<'g> {
    g: &'g Graph,
}

impl<'g> Bfs<'g> {
    pub fn new(g: &'g Graph) -> Self {
        Self { g }
    }
}

impl<'g> QueryApp for Bfs<'g> {
    type Query = PpspQuery;
    /// d(s, v) estimate.
    type VQ = u32;
    /// Pure activation: payload-free (distance is derived from the step).
    type Msg = ();
    type Agg = ();
    /// `Some(d(s, t))` or `None` if unreachable.
    type Out = Option<u32>;

    fn init_activate(&self, q: &PpspQuery) -> Vec<VertexId> {
        vec![q.0]
    }

    fn init_value(&self, q: &PpspQuery, v: VertexId) -> u32 {
        if v == q.0 {
            0
        } else {
            UNREACHED
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, d: &mut u32) {
        let step = ctx.superstep();
        let (_, t) = *ctx.query();
        if step == 1 {
            // v must be s (only s is in V_q^I).
            if v == t {
                ctx.force_terminate(); // s == t: d = 0 already recorded
            }
            for &u in self.g.out(v) {
                ctx.send(u, ());
            }
            ctx.vote_halt();
            return;
        }
        if *d == UNREACHED {
            // First visit.
            *d = (step - 1) as u32;
            if v == t {
                ctx.force_terminate();
            } else {
                for &u in self.g.out(v) {
                    ctx.send(u, ());
                }
            }
        }
        // Already-visited vertices just halt.
        ctx.vote_halt();
    }

    /// Activation messages are idempotent: combine everything into one.
    fn combine(&self, _into: &mut (), _from: &()) -> bool {
        true
    }

    fn finish(
        &self,
        q: &PpspQuery,
        touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &(),
    ) -> Option<u32> {
        let t = q.1;
        for (v, &d) in touched {
            if v == t && d != UNREACHED {
                return Some(d);
            }
        }
        None
    }

    fn msg_bytes(&self) -> usize {
        1 // activation flag on the wire
    }
}

#[cfg(test)]
mod tests {
    use super::super::oracle;
    use super::*;
    use crate::coordinator::Engine;
    use crate::graph::gen;
    use crate::network::Cluster;

    #[test]
    fn bfs_matches_oracle_on_random_graph() {
        let g = gen::twitter_like(500, 4, 11);
        let app = Bfs::new(&g);
        let mut eng = Engine::new(app, Cluster::new(4), g.num_vertices());
        for (s, t) in gen::random_pairs(500, 10, 12) {
            let want = oracle::bfs_dist(&g, s, t);
            let got = eng.run_one((s, t)).out;
            if want == UNREACHED {
                assert_eq!(got, None, "({s},{t})");
            } else {
                assert_eq!(got, Some(want), "({s},{t})");
            }
        }
    }

    #[test]
    fn self_query_is_zero() {
        let g = gen::twitter_like(100, 3, 1);
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(2), 100);
        assert_eq!(eng.run_one((7, 7)).out, Some(0));
    }

    #[test]
    fn early_termination_limits_access() {
        // On a long path 0-1-2-...-99, query (0, 1) must touch far fewer
        // vertices than the whole graph.
        let mut b = crate::graph::GraphBuilder::new(100).undirected();
        for i in 0..99u32 {
            b.edge(i, i + 1);
        }
        let g = b.build();
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 100);
        let r = eng.run_one((0, 1));
        assert_eq!(r.out, Some(1));
        assert!(
            r.stats.touched < 10,
            "force_terminate must stop the sweep, touched {}",
            r.stats.touched
        );
    }
}
