//! Point-to-point shortest-path (PPSP) queries on unweighted graphs
//! (paper §5.1): plain BFS, bidirectional BFS, and the Hub²-indexed
//! algorithm, plus a serial oracle for testing. The streaming-mutation
//! variants read through the epoch overlay instead of a borrowed CSR:
//! [`VersionedBfs`] (index-free) and [`Hub2Serve`] (with incremental
//! index maintenance by [`Hub2Maintainer`]).

pub mod bfs;
pub mod bibfs;
pub mod hub2;
pub mod oracle;
pub mod vbfs;

pub use bfs::Bfs;
pub use bibfs::BiBfs;
pub use hub2::{lazy_serve_query, Hub2Index, Hub2Indexer, Hub2Maintainer, Hub2Query, Hub2Serve};
pub use vbfs::{vbfs_query, VersionedBfs};

/// "Infinite" hop count for unreachable pairs.
pub const UNREACHED: u32 = u32::MAX;

/// A PPSP query: find the minimum number of hops from `s` to `t`.
pub type PpspQuery = (crate::graph::VertexId, crate::graph::VertexId);
