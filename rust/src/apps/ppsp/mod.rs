//! Point-to-point shortest-path (PPSP) queries on unweighted graphs
//! (paper §5.1): plain BFS, bidirectional BFS, and the Hub²-indexed
//! algorithm, plus a serial oracle for testing.

pub mod bfs;
pub mod bibfs;
pub mod hub2;
pub mod oracle;

pub use bfs::Bfs;
pub use bibfs::BiBfs;
pub use hub2::{Hub2Index, Hub2Indexer, Hub2Query};

/// "Infinite" hop count for unreachable pairs.
pub const UNREACHED: u32 = u32::MAX;

/// A PPSP query: find the minimum number of hops from `s` to `t`.
pub type PpspQuery = (crate::graph::VertexId, crate::graph::VertexId);
