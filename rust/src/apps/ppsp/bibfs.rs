//! Bidirectional BFS for PPSP queries (paper §5.1.1).
//!
//! Forward BFS from `s` (out-edges) and backward BFS from `t` (in-edges)
//! run in parallel with direction-tagged messages. `a_q(v)` keeps the pair
//! (d(s,v), d(v,t)). When any vertex is bi-reached, it contributes
//! d(s,v) + d(v,t) to the aggregator and force-terminates; the master takes
//! the minimum over all bi-reached vertices (sums may be 2i-1 or 2i).
//! The aggregator also counts messages per direction: if either direction
//! sends none, the query stops with d = ∞ (the small-CC early stop).

use super::{PpspQuery, UNREACHED};
use crate::graph::{Graph, VertexId};
use crate::vertex::{Ctx, MasterAction, QueryApp};

/// Direction bitmask carried by messages.
pub const FWD: u8 = 1;
pub const BWD: u8 = 2;

/// Per-vertex state: distances from s and to t.
#[derive(Debug, Clone)]
pub struct BiState {
    pub ds: u32,
    pub dt: u32,
}

/// Aggregator: best bi-reached sum + per-direction message counts.
#[derive(Debug, Clone)]
pub struct BiAgg {
    pub best: u32,
    pub fwd_sent: u64,
    pub bwd_sent: u64,
}

impl Default for BiAgg {
    fn default() -> Self {
        Self {
            best: UNREACHED,
            fwd_sent: 0,
            bwd_sent: 0,
        }
    }
}

/// Bidirectional BFS PPSP application. Requires `g.ensure_in_edges()`.
pub struct BiBfs<'g> {
    g: &'g Graph,
}

impl<'g> BiBfs<'g> {
    pub fn new(g: &'g Graph) -> Self {
        assert!(
            g.has_in_edges(),
            "BiBFS needs in-adjacency: call ensure_in_edges() first"
        );
        Self { g }
    }

    fn broadcast_fwd(&self, ctx: &mut Ctx<'_, Self>, v: VertexId) {
        for &u in self.g.out(v) {
            ctx.send(u, FWD);
        }
        let n = self.g.out(v).len() as u64;
        ctx.aggregate(|_, a| a.fwd_sent += n);
    }

    fn broadcast_bwd(&self, ctx: &mut Ctx<'_, Self>, v: VertexId) {
        for &u in self.g.inn(v) {
            ctx.send(u, BWD);
        }
        let n = self.g.inn(v).len() as u64;
        ctx.aggregate(|_, a| a.bwd_sent += n);
    }
}

impl<'g> QueryApp for BiBfs<'g> {
    type Query = PpspQuery;
    type VQ = BiState;
    /// Direction bitmask (FWD | BWD).
    type Msg = u8;
    type Agg = BiAgg;
    type Out = Option<u32>;

    fn init_activate(&self, q: &PpspQuery) -> Vec<VertexId> {
        if q.0 == q.1 {
            vec![q.0]
        } else {
            vec![q.0, q.1]
        }
    }

    fn init_value(&self, q: &PpspQuery, v: VertexId) -> BiState {
        BiState {
            ds: if v == q.0 { 0 } else { UNREACHED },
            dt: if v == q.1 { 0 } else { UNREACHED },
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut BiState) {
        let step = ctx.superstep();
        let (s, t) = *ctx.query();
        if step == 1 {
            if s == t {
                // d(s, t) = 0; report via aggregator.
                ctx.aggregate(|_, a| a.best = 0);
                ctx.force_terminate();
                ctx.vote_halt();
                return;
            }
            if v == s {
                self.broadcast_fwd(ctx, v);
            }
            if v == t {
                self.broadcast_bwd(ctx, v);
            }
            ctx.vote_halt();
            return;
        }
        let mut mask = 0u8;
        for &m in ctx.msgs() {
            mask |= m;
        }
        let newly_fwd = mask & FWD != 0 && st.ds == UNREACHED;
        let newly_bwd = mask & BWD != 0 && st.dt == UNREACHED;
        if newly_fwd {
            st.ds = (step - 1) as u32;
        }
        if newly_bwd {
            st.dt = (step - 1) as u32;
        }
        if st.ds != UNREACHED && st.dt != UNREACHED && (newly_fwd || newly_bwd) {
            // Bi-reached: contribute and stop the query at this barrier.
            let sum = st.ds.saturating_add(st.dt);
            ctx.aggregate(|_, a| a.best = a.best.min(sum));
            ctx.force_terminate();
            ctx.vote_halt();
            return;
        }
        if newly_fwd {
            self.broadcast_fwd(ctx, v);
        }
        if newly_bwd {
            self.broadcast_bwd(ctx, v);
        }
        ctx.vote_halt();
    }

    /// Direction masks combine by OR.
    fn combine(&self, into: &mut u8, from: &u8) -> bool {
        *into |= *from;
        true
    }

    fn agg_merge(&self, into: &mut BiAgg, from: &BiAgg) {
        into.best = into.best.min(from.best);
        into.fwd_sent += from.fwd_sent;
        into.bwd_sent += from.bwd_sent;
    }

    fn master_step(
        &self,
        _q: &PpspQuery,
        step: u64,
        prev: &BiAgg,
        agg: &mut BiAgg,
    ) -> MasterAction {
        agg.best = agg.best.min(prev.best);
        if agg.best != UNREACHED {
            return MasterAction::Terminate;
        }
        // Zero messages in either direction => that BFS is exhausted and no
        // meeting point can exist (paper's disconnected-CC early stop).
        if step >= 1 && (agg.fwd_sent == 0 || agg.bwd_sent == 0) {
            return MasterAction::Terminate;
        }
        // Reset per-step message counters; keep best across steps.
        agg.fwd_sent = 0;
        agg.bwd_sent = 0;
        MasterAction::Continue
    }

    fn finish(
        &self,
        _q: &PpspQuery,
        _touched: &mut dyn Iterator<Item = (VertexId, &BiState)>,
        agg: &BiAgg,
    ) -> Option<u32> {
        (agg.best != UNREACHED).then_some(agg.best)
    }

    fn msg_bytes(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::super::oracle;
    use super::*;
    use crate::coordinator::Engine;
    use crate::graph::gen;
    use crate::network::Cluster;

    fn with_in(mut g: Graph) -> Graph {
        g.ensure_in_edges();
        g
    }

    #[test]
    fn bibfs_matches_oracle_directed() {
        let g = with_in(gen::twitter_like(400, 4, 21));
        let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(4), g.num_vertices());
        for (s, t) in gen::random_pairs(400, 15, 22) {
            let want = oracle::bfs_dist(&g, s, t);
            let got = eng.run_one((s, t)).out;
            assert_eq!(
                got,
                (want != UNREACHED).then_some(want),
                "query ({s},{t}) want {want}"
            );
        }
    }

    #[test]
    fn bibfs_matches_oracle_undirected_multi_cc() {
        let g = with_in(gen::btc_like(600, 60, 4, 23));
        let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(3), g.num_vertices());
        for (s, t) in gen::random_pairs(600, 15, 24) {
            let want = oracle::bfs_dist(&g, s, t);
            let got = eng.run_one((s, t)).out;
            assert_eq!(got, (want != UNREACHED).then_some(want), "({s},{t})");
        }
    }

    #[test]
    fn self_query() {
        let g = with_in(gen::twitter_like(50, 3, 2));
        let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(2), 50);
        assert_eq!(eng.run_one((5, 5)).out, Some(0));
    }

    #[test]
    fn small_cc_early_stop_bounds_supersteps() {
        // s in a 3-vertex island, t in a long path: the zero-message early
        // stop must fire quickly instead of sweeping t's component.
        let mut b = crate::graph::GraphBuilder::new(103).undirected();
        b.edge(100, 101);
        b.edge(101, 102);
        for i in 0..99u32 {
            b.edge(i, i + 1);
        }
        let g = with_in(b.build());
        let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(2), 103);
        let r = eng.run_one((100, 0));
        assert_eq!(r.out, None);
        assert!(
            r.stats.supersteps < 10,
            "early stop should bound supersteps, got {}",
            r.stats.supersteps
        );
    }
}
