//! Serial BFS oracle used by tests and property checks.

use super::UNREACHED;
use crate::graph::{Graph, VertexId};

/// Serial single-source BFS distance from `s` to `t` (hops), following
/// out-edges. Returns `UNREACHED` if `t` is not reachable.
pub fn bfs_dist(g: &Graph, s: VertexId, t: VertexId) -> u32 {
    if s == t {
        return 0;
    }
    let n = g.num_vertices();
    let mut dist = vec![UNREACHED; n];
    dist[s as usize] = 0;
    let mut frontier = vec![s];
    let mut d = 0;
    while !frontier.is_empty() {
        d += 1;
        let mut next = Vec::new();
        for &u in &frontier {
            for &v in g.out(u) {
                if dist[v as usize] == UNREACHED {
                    if v == t {
                        return d;
                    }
                    dist[v as usize] = d;
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    UNREACHED
}

/// Full single-source BFS distance vector (hops along out-edges).
pub fn bfs_all(g: &Graph, s: VertexId) -> Vec<u32> {
    let n = g.num_vertices();
    let mut dist = vec![UNREACHED; n];
    dist[s as usize] = 0;
    let mut frontier = vec![s];
    let mut d = 0;
    while !frontier.is_empty() {
        d += 1;
        let mut next = Vec::new();
        for &u in &frontier {
            for &v in g.out(u) {
                if dist[v as usize] == UNREACHED {
                    dist[v as usize] = d;
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path_graph(n: usize) -> Graph {
        let mut b = GraphBuilder::new(n).undirected();
        for i in 0..n - 1 {
            b.edge(i as VertexId, (i + 1) as VertexId);
        }
        b.build()
    }

    #[test]
    fn path_distances() {
        let g = path_graph(6);
        assert_eq!(bfs_dist(&g, 0, 5), 5);
        assert_eq!(bfs_dist(&g, 2, 2), 0);
        assert_eq!(bfs_dist(&g, 5, 0), 5);
    }

    #[test]
    fn unreachable() {
        let mut b = GraphBuilder::new(4);
        b.edge(0, 1);
        b.edge(2, 3);
        let g = b.build();
        assert_eq!(bfs_dist(&g, 0, 3), UNREACHED);
    }

    #[test]
    fn bfs_all_matches_pointwise() {
        let g = path_graph(5);
        let d = bfs_all(&g, 1);
        assert_eq!(d, vec![1, 0, 1, 2, 3]);
    }
}
