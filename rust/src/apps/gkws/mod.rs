//! Graph (RDF) keyword search (paper §5.5): RDF triples → adjacency
//! representation, inverted keyword index, and the δ_max-bounded
//! multi-source search with the four RDF message cases.

pub mod data;
pub mod query;

pub use data::{RdfGenConfig, RdfGraph};
pub use query::{GkwsQuery, KeywordSearch};
