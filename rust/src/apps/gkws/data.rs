//! RDF substrate for graph keyword search (paper §5.5).
//!
//! Triples (s, p, o) are converted to the paper's adjacency representation:
//! for each *resource* vertex v we store Γ_in(v) (in-neighbors with their
//! predicate word) and A(v) (literal attributes with their predicate word);
//! literals are folded into their owning resource. A keyword inverted index
//! activates vertices for any of the four match cases of Figure 8.

use crate::graph::VertexId;
use crate::util::{FxHashMap, FxHashSet, Rng};

/// The adjacency representation of an RDF graph.
#[derive(Debug, Default)]
pub struct RdfGraph {
    /// Γ_in(v): (in-neighbor resource, predicate word id).
    pub in_nbrs: Vec<Vec<(VertexId, u32)>>,
    /// Out-edges (v → w, predicate word id) — needed to forward fields.
    pub out_nbrs: Vec<Vec<(VertexId, u32)>>,
    /// A(v): literal attributes (literal word ids, predicate word id).
    pub literals: Vec<Vec<(Vec<u32>, u32)>>,
    /// ψ(v): words of the resource's own text (URI tokens).
    pub text: Vec<Vec<u32>>,
    /// word -> id interning.
    pub vocab: FxHashMap<String, u32>,
    pub words: Vec<String>,
    /// Inverted index: word -> vertices to activate (any of the 4 cases).
    pub inverted: FxHashMap<u32, Vec<VertexId>>,
}

impl RdfGraph {
    /// Number of resource vertices.
    pub fn len(&self) -> usize {
        self.text.len()
    }

    /// True if there are no vertices.
    pub fn is_empty(&self) -> bool {
        self.text.is_empty()
    }

    /// Intern a word.
    pub fn intern(&mut self, w: &str) -> u32 {
        if let Some(&id) = self.vocab.get(w) {
            return id;
        }
        let id = self.words.len() as u32;
        self.vocab.insert(w.to_string(), id);
        self.words.push(w.to_string());
        id
    }

    /// Add a resource vertex with its text words.
    pub fn add_resource(&mut self, text: Vec<u32>) -> VertexId {
        let v = self.text.len() as VertexId;
        self.text.push(text);
        self.in_nbrs.push(Vec::new());
        self.out_nbrs.push(Vec::new());
        self.literals.push(Vec::new());
        v
    }

    /// Add a triple between resources: (s, p, o).
    pub fn add_edge(&mut self, s: VertexId, p: u32, o: VertexId) {
        self.out_nbrs[s as usize].push((o, p));
        self.in_nbrs[o as usize].push((s, p));
    }

    /// Add a literal triple: (s, p, "literal words").
    pub fn add_literal(&mut self, s: VertexId, p: u32, words: Vec<u32>) {
        self.literals[s as usize].push((words, p));
    }

    /// Build the activation index: a vertex v is activated by word k when
    /// k ∈ ψ(v) (case 1), k appears in a literal value or literal predicate
    /// of A(v) (case 2), or k appears in the predicate of an in-edge of v
    /// (case 4; v is the *object* side that sends ⟨v, 0⟩ to the subject).
    pub fn build_inverted_index(&mut self) {
        let mut inv: FxHashMap<u32, FxHashSet<VertexId>> = FxHashMap::default();
        for v in 0..self.len() as VertexId {
            for &w in &self.text[v as usize] {
                inv.entry(w).or_default().insert(v);
            }
            for (lw, p) in &self.literals[v as usize] {
                inv.entry(*p).or_default().insert(v);
                for &w in lw {
                    inv.entry(w).or_default().insert(v);
                }
            }
            for &(_, p) in &self.in_nbrs[v as usize] {
                inv.entry(p).or_default().insert(v);
            }
        }
        self.inverted = inv
            .into_iter()
            .map(|(w, set)| {
                let mut v: Vec<VertexId> = set.into_iter().collect();
                v.sort_unstable();
                (w, v)
            })
            .collect();
    }

    /// Activation set for a query.
    pub fn matching_vertices(&self, q: &[u32]) -> Vec<VertexId> {
        let mut out = Vec::new();
        for w in q {
            if let Some(vs) = self.inverted.get(w) {
                out.extend_from_slice(vs);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Approximate in-memory size (for load-cost modeling).
    pub fn footprint_bytes(&self) -> usize {
        let edges: usize = self.in_nbrs.iter().map(|e| e.len() * 8 * 2).sum();
        let lits: usize = self
            .literals
            .iter()
            .flat_map(|l| l.iter().map(|(w, _)| w.len() * 4 + 4))
            .sum();
        edges + lits + self.len() * 16
    }
}

/// Generator config for Freebase/DBPedia-like synthetic RDF.
#[derive(Debug, Clone)]
pub struct RdfGenConfig {
    pub resources: usize,
    /// Average resource-to-resource out-degree.
    pub avg_deg: usize,
    /// Number of distinct predicates (Zipf-used).
    pub predicates: usize,
    /// Literal vocabulary size.
    pub vocab: usize,
    pub seed: u64,
}

/// Generate a synthetic RDF graph.
pub fn generate(cfg: &RdfGenConfig) -> RdfGraph {
    let mut rng = Rng::new(cfg.seed);
    let mut g = RdfGraph::default();
    let preds: Vec<u32> = (0..cfg.predicates)
        .map(|i| g.intern(&format!("p{i}")))
        .collect();
    let vocab: Vec<u32> = (0..cfg.vocab)
        .map(|i| g.intern(&format!("k{i}")))
        .collect();
    // Resources: URI-ish text = one or two vocabulary words.
    for _ in 0..cfg.resources {
        let nw = 1 + rng.below_usize(2);
        let words = (0..nw)
            .map(|_| vocab[rng.zipf(vocab.len(), 1.1)])
            .collect();
        g.add_resource(words);
    }
    let n = cfg.resources;
    // Resource-to-resource triples with Zipf-popular objects.
    let mut seen = FxHashSet::default();
    for s in 0..n {
        let deg = 1 + rng.below_usize(cfg.avg_deg * 2 - 1);
        for _ in 0..deg {
            let o = rng.zipf(n, 1.2) as VertexId;
            let p = preds[rng.zipf(preds.len(), 1.3)];
            if o as usize != s && seen.insert((s as VertexId, o, p)) {
                g.add_edge(s as VertexId, p, o);
            }
        }
    }
    // Literal attributes.
    for s in 0..n {
        for _ in 0..1 + rng.below_usize(3) {
            let p = preds[rng.zipf(preds.len(), 1.3)];
            let nw = 1 + rng.below_usize(3);
            let words = (0..nw)
                .map(|_| vocab[rng.zipf(vocab.len(), 1.1)])
                .collect();
            g.add_literal(s as VertexId, p, words);
        }
    }
    g.build_inverted_index();
    g
}

/// Build keyword query pools the paper's way (§6): k1 with relatively low
/// selectivity, k2/k3 relevant co-occurring words. We sample k1 from the
/// band of words matching ~0.1-1% of vertices (queries on an 11M-vertex
/// Freebase touch 3.4% — seeds must be sparse or the δ_max ball floods the
/// graph) and k2/k3 from the moderately-frequent tail.
pub fn query_pool(g: &RdfGraph, count: usize, m: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    let n = g.len().max(1);
    let mut freq: Vec<(u32, usize)> = g
        .inverted
        .iter()
        .map(|(&w, vs)| (w, vs.len()))
        .collect();
    freq.sort_by_key(|&(w, c)| (std::cmp::Reverse(c), w));
    // k1 band: matches between 0.05% and 1% of vertices.
    let head: Vec<u32> = freq
        .iter()
        .filter(|&&(_, c)| c * 1000 >= n / 2 && c * 100 <= n)
        .map(|&(w, _)| w)
        .collect();
    let head = if head.is_empty() {
        freq.iter().skip(freq.len() / 4).take(50).map(|&(w, _)| w).collect()
    } else {
        head
    };
    // k2/k3 band: the moderately-frequent tail.
    let lo = freq.len() / 4;
    let hi = freq.len().min(lo + 600);
    let band: Vec<u32> = freq[lo..hi].iter().map(|&(w, _)| w).collect();
    (0..count)
        .map(|_| {
            let mut q = vec![head[rng.below_usize(head.len())]];
            while q.len() < m {
                let w = band[rng.below_usize(band.len())];
                if !q.contains(&w) {
                    q.push(w);
                }
            }
            q
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> RdfGraph {
        generate(&RdfGenConfig {
            resources: 500,
            avg_deg: 3,
            predicates: 20,
            vocab: 100,
            seed: 91,
        })
    }

    #[test]
    fn generator_shape() {
        let g = small();
        assert_eq!(g.len(), 500);
        let edges: usize = g.out_nbrs.iter().map(Vec::len).sum();
        assert!(edges >= 500);
        // In/out adjacency must mirror each other.
        let in_edges: usize = g.in_nbrs.iter().map(Vec::len).sum();
        assert_eq!(edges, in_edges);
    }

    #[test]
    fn inverted_index_covers_all_cases() {
        let mut g = RdfGraph::default();
        let supervises = g.intern("supervises");
        let age = g.intern("age");
        let tom_w = g.intern("tom");
        let peter_w = g.intern("peter");
        let lit = g.intern("25");
        let tom = g.add_resource(vec![tom_w]);
        let peter = g.add_resource(vec![peter_w]);
        g.add_edge(tom, supervises, peter);
        g.add_literal(peter, age, vec![lit]);
        g.build_inverted_index();
        // case 1: own text
        assert_eq!(g.inverted[&tom_w], vec![tom]);
        // case 2: literal value + literal predicate activate the owner
        assert_eq!(g.inverted[&lit], vec![peter]);
        assert_eq!(g.inverted[&age], vec![peter]);
        // case 4: in-edge predicate activates the object
        assert_eq!(g.inverted[&supervises], vec![peter]);
    }

    #[test]
    fn query_pool_shape() {
        let g = small();
        for q in query_pool(&g, 30, 3, 92) {
            assert_eq!(q.len(), 3);
            let set: FxHashSet<u32> = q.iter().copied().collect();
            assert_eq!(set.len(), 3);
        }
    }
}
