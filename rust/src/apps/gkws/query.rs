//! The δ_max-bounded RDF keyword search query (paper §5.5).
//!
//! Each vertex maintains, per keyword k_i, its closest matching entity
//! ⟨v_i, hop(v, v_i)⟩. Fields flow along *in*-edges (a root must reach its
//! matches via out-edges). Superstep 1 applies the four RDF cases of
//! Figure 8 (own text → ⟨v,0⟩; literal value/predicate → ⟨ℓ,1⟩; existing
//! field; in-edge predicate → targeted ⟨v,0⟩); later supersteps relax and
//! forward improved fields. After δ_max supersteps everything halts; any
//! vertex with all m fields set is an answer root.

use super::data::RdfGraph;
use crate::graph::VertexId;
use crate::vertex::{Ctx, MasterAction, QueryApp};

/// Unset match-entity sentinel.
pub const UNSET: VertexId = VertexId::MAX;

/// Query content: keyword ids + the hop bound δ_max.
#[derive(Debug, Clone)]
pub struct GkwsQuery {
    pub keywords: Vec<u32>,
    pub delta_max: u32,
}

/// One per-keyword field ⟨v_i, hop⟩.
pub type Field = (VertexId, u32);

/// A result root: vertex + per-keyword (match, hop).
pub type GkwsRoot = (VertexId, Vec<Field>);

/// Keyword-search app over an [`RdfGraph`].
pub struct KeywordSearch<'g> {
    g: &'g RdfGraph,
}

impl<'g> KeywordSearch<'g> {
    pub fn new(g: &'g RdfGraph) -> Self {
        Self { g }
    }

    /// The four-case superstep-1 send logic for keyword `ki` at vertex `v`.
    /// Returns the field v initializes for itself (if any); sends happen
    /// through `send`: (destination, message).
    fn step1_case(
        &self,
        v: VertexId,
        k: u32,
        send: &mut impl FnMut(VertexId, (u8, VertexId, u32)),
        ki: u8,
    ) -> Field {
        let g = self.g;
        // Case 1: own text matches — broadcast ⟨v, 0⟩.
        if g.text[v as usize].contains(&k) {
            for &(u, _) in &g.in_nbrs[v as usize] {
                send(u, (ki, v, 0));
            }
            return (v, 0);
        }
        // Case 2: literal value or literal predicate — broadcast ⟨ℓ, 1⟩
        // (the literal is one hop from v; we report v as the entity carrying
        // it, at hop 1).
        if g.literals[v as usize]
            .iter()
            .any(|(lw, p)| *p == k || lw.contains(&k))
        {
            for &(u, _) in &g.in_nbrs[v as usize] {
                send(u, (ki, v, 1));
            }
            return (v, 1);
        }
        // Case 3 cannot apply at superstep 1 (no field yet).
        // Case 4: in-edge predicate matches — targeted ⟨v, 0⟩ to that u.
        for &(u, p) in &g.in_nbrs[v as usize] {
            if p == k {
                send(u, (ki, v, 0));
            }
        }
        (UNSET, u32::MAX)
    }
}

impl<'g> QueryApp for KeywordSearch<'g> {
    type Query = GkwsQuery;
    /// Per-keyword closest-match fields.
    type VQ = Vec<Field>;
    /// (keyword index, match entity, hop *at the sender*).
    type Msg = (u8, VertexId, u32);
    type Agg = ();
    type Out = Vec<GkwsRoot>;

    fn init_activate(&self, q: &GkwsQuery) -> Vec<VertexId> {
        self.g.matching_vertices(&q.keywords)
    }

    fn init_value(&self, q: &GkwsQuery, _v: VertexId) -> Vec<Field> {
        vec![(UNSET, u32::MAX); q.keywords.len()]
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, fields: &mut Vec<Field>) {
        let q = ctx.query().clone();
        if ctx.superstep() == 1 {
            let mut staged: Vec<(VertexId, (u8, VertexId, u32))> = Vec::new();
            for (i, &k) in q.keywords.iter().enumerate() {
                let mut send = |dst: VertexId, m: (u8, VertexId, u32)| staged.push((dst, m));
                let f = self.step1_case(v, k, &mut send, i as u8);
                if f.0 != UNSET {
                    fields[i] = f;
                }
            }
            for (dst, m) in staged {
                ctx.send(dst, m);
            }
            ctx.vote_halt();
            return;
        }
        // Relaxation: receiving ⟨x, h⟩ from an out-neighbor means x is
        // h + 1 hops from here.
        let mut improved: Vec<u8> = Vec::new();
        for &(ki, x, h) in ctx.msgs() {
            let cand = h + 1;
            let f = &mut fields[ki as usize];
            if cand < f.1 {
                *f = (x, cand);
                improved.push(ki);
            }
        }
        improved.sort_unstable();
        improved.dedup();
        for ki in improved {
            let (x, h) = fields[ki as usize];
            if h < q.delta_max {
                // Forward only while the next hop stays within δ_max.
                for &(u, _) in &self.g.in_nbrs[v as usize] {
                    ctx.send(u, (ki, x, h));
                }
            }
        }
        ctx.vote_halt();
    }

    /// Min-hop combiner per keyword: since messages for different keywords
    /// must coexist, only combine equal-keyword messages.
    fn combine(&self, into: &mut (u8, VertexId, u32), from: &(u8, VertexId, u32)) -> bool {
        if into.0 == from.0 {
            if from.2 < into.2 {
                *into = *from;
            }
            return true;
        }
        false
    }

    fn master_step(&self, q: &GkwsQuery, step: u64, _prev: &(), _cur: &mut ()) -> MasterAction {
        if step >= q.delta_max as u64 + 1 {
            // δ_max propagation supersteps have run; stop everything.
            return MasterAction::Terminate;
        }
        MasterAction::Continue
    }

    fn finish(
        &self,
        q: &GkwsQuery,
        touched: &mut dyn Iterator<Item = (VertexId, &Vec<Field>)>,
        _agg: &(),
    ) -> Vec<GkwsRoot> {
        let mut out: Vec<GkwsRoot> = Vec::new();
        for (v, fields) in touched {
            if fields.iter().all(|f| f.0 != UNSET && f.1 <= q.delta_max) {
                out.push((v, fields.clone()));
            }
        }
        out.sort_unstable_by_key(|r| r.0);
        out
    }

    fn msg_bytes(&self) -> usize {
        9
    }
}

/// Serial oracle: simulate the same BSP rounds without the engine (used by
/// tests to validate routing/combining/termination in the engine path).
pub fn oracle(g: &RdfGraph, q: &GkwsQuery) -> Vec<GkwsRoot> {
    let n = g.len();
    let m = q.keywords.len();
    let mut fields = vec![vec![(UNSET, u32::MAX); m]; n];
    // (dst, ki, entity, hop-at-sender)
    let mut inbox: Vec<(VertexId, u8, VertexId, u32)> = Vec::new();
    let ks = KeywordSearch::new(g);
    for v in g.matching_vertices(&q.keywords) {
        for (i, &k) in q.keywords.iter().enumerate() {
            let mut send =
                |dst: VertexId, msg: (u8, VertexId, u32)| inbox.push((dst, msg.0, msg.1, msg.2));
            let f = ks.step1_case(v, k, &mut send, i as u8);
            if f.0 != UNSET {
                fields[v as usize][i as usize] = f;
            }
        }
    }
    for _step in 2..=(q.delta_max as usize + 1) {
        let mut next = Vec::new();
        let mut improved: Vec<(VertexId, u8)> = Vec::new();
        for (dst, ki, x, h) in inbox.drain(..) {
            let cand = h + 1;
            let f = &mut fields[dst as usize][ki as usize];
            if cand < f.1 {
                *f = (x, cand);
                improved.push((dst, ki));
            }
        }
        improved.sort_unstable();
        improved.dedup();
        for (v, ki) in improved {
            let (x, h) = fields[v as usize][ki as usize];
            if h < q.delta_max {
                for &(u, _) in &g.in_nbrs[v as usize] {
                    next.push((u, ki, x, h));
                }
            }
        }
        inbox = next;
    }
    let mut out: Vec<GkwsRoot> = fields
        .into_iter()
        .enumerate()
        .filter(|(_, f)| f.iter().all(|x| x.0 != UNSET && x.1 <= q.delta_max))
        .map(|(v, f)| (v as VertexId, f))
        .collect();
    out.sort_unstable_by_key(|r| r.0);
    out
}

#[cfg(test)]
mod tests {
    use super::super::data::{generate, query_pool, RdfGenConfig};
    use super::*;
    use crate::coordinator::Engine;
    use crate::network::Cluster;

    fn small(seed: u64) -> RdfGraph {
        generate(&RdfGenConfig {
            resources: 400,
            avg_deg: 3,
            predicates: 15,
            vocab: 80,
            seed,
        })
    }

    #[test]
    fn distributed_matches_oracle() {
        for seed in [101, 102] {
            let g = small(seed);
            for (m, dmax) in [(2usize, 3u32), (3, 3), (2, 2)] {
                for kw in query_pool(&g, 8, m, seed + 7) {
                    let q = GkwsQuery {
                        keywords: kw,
                        delta_max: dmax,
                    };
                    let want = oracle(&g, &q);
                    let mut eng = Engine::new(KeywordSearch::new(&g), Cluster::new(4), g.len());
                    let got = eng.run_one(q.clone()).out;
                    // Hop values are unique; the matched *entity* may differ
                    // at ties (message-order dependent, both answers valid).
                    let project = |rs: &[GkwsRoot]| -> Vec<(VertexId, Vec<u32>)> {
                        rs.iter()
                            .map(|(v, f)| (*v, f.iter().map(|&(_, h)| h).collect()))
                            .collect()
                    };
                    assert_eq!(project(&got), project(&want), "q={q:?}");
                }
            }
        }
    }

    #[test]
    fn figure7_example() {
        // Tom --supervises--> Peter --age--> "25"
        let mut g = RdfGraph::default();
        let supervises = g.intern("supervises");
        let age = g.intern("age");
        let tom_w = g.intern("tom");
        let peter_w = g.intern("peter");
        let lit25 = g.intern("25");
        let tom = g.add_resource(vec![tom_w]);
        let peter = g.add_resource(vec![peter_w]);
        g.add_edge(tom, supervises, peter);
        g.add_literal(peter, age, vec![lit25]);
        g.build_inverted_index();

        // Query {tom, 25}: root Tom covers "tom" at 0 and "25" at 2
        // (Peter's literal, one hop to Peter + literal hop).
        let q = GkwsQuery {
            keywords: vec![tom_w, lit25],
            delta_max: 3,
        };
        let mut eng = Engine::new(KeywordSearch::new(&g), Cluster::new(2), g.len());
        let roots = eng.run_one(q).out;
        let tom_root = roots.iter().find(|r| r.0 == tom).expect("tom is a root");
        assert_eq!(tom_root.1[0], (tom, 0));
        assert_eq!(tom_root.1[1], (peter, 2));
    }

    #[test]
    fn delta_max_bounds_results() {
        let g = small(103);
        let kw = query_pool(&g, 1, 2, 104).pop().unwrap();
        let tight = GkwsQuery {
            keywords: kw.clone(),
            delta_max: 1,
        };
        let loose = GkwsQuery {
            keywords: kw,
            delta_max: 4,
        };
        let mut e1 = Engine::new(KeywordSearch::new(&g), Cluster::new(4), g.len());
        let r1 = e1.run_one(tight).out;
        let mut e2 = Engine::new(KeywordSearch::new(&g), Cluster::new(4), g.len());
        let r2 = e2.run_one(loose).out;
        assert!(r1.len() <= r2.len(), "tighter bound must not add roots");
        for (_, fields) in &r1 {
            for f in fields {
                assert!(f.1 <= 1);
            }
        }
    }

    #[test]
    fn more_keywords_cost_more_access() {
        // Table 12's trend: 3-keyword queries touch more than 2-keyword.
        let g = small(105);
        let q2 = query_pool(&g, 10, 2, 106);
        let q3 = query_pool(&g, 10, 3, 106);
        let mut t2 = 0u64;
        let mut t3 = 0u64;
        for kw in q2 {
            let mut e = Engine::new(KeywordSearch::new(&g), Cluster::new(4), g.len());
            t2 += e
                .run_one(GkwsQuery {
                    keywords: kw,
                    delta_max: 3,
                })
                .stats
                .touched;
        }
        for kw in q3 {
            let mut e = Engine::new(KeywordSearch::new(&g), Cluster::new(4), g.len());
            t3 += e
                .run_one(GkwsQuery {
                    keywords: kw,
                    delta_max: 3,
                })
                .stats
                .touched;
        }
        assert!(t3 > t2, "3-kw {t3} !> 2-kw {t2}");
    }
}
