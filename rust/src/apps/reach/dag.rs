//! SCC condensation and DFS-forest orders (paper §5.4 preprocessing).
//!
//! The paper computes SCCs with a separate Pregel job [36] and the DFS
//! forest with an IO-efficient external algorithm [42], both *offline*
//! preprocessing steps whose outputs Quegel loads as index data. Here we
//! compute them with serial in-memory algorithms (iterative Tarjan and
//! iterative DFS), which produce identical artifacts.

use crate::graph::{Graph, GraphBuilder, VertexId};
use crate::util::FxHashSet;

/// Condensation of a digraph: the DAG of SCCs plus the v → SCC map.
pub struct Condensation {
    /// scc_of[v] = DAG vertex id of v's strongly connected component.
    pub scc_of: Vec<VertexId>,
    /// The condensed DAG (one vertex per SCC, deduped edges).
    pub dag: Graph,
    /// Number of SCCs.
    pub num_sccs: usize,
}

/// Iterative Tarjan SCC + condensation.
pub fn condense(g: &Graph) -> Condensation {
    let n = g.num_vertices();
    const UNSET: u32 = u32::MAX;
    let mut index = vec![UNSET; n];
    let mut low = vec![0u32; n];
    let mut on_stack = vec![false; n];
    let mut scc_of = vec![UNSET; n];
    let mut stack: Vec<VertexId> = Vec::new();
    let mut next_index = 0u32;
    let mut num_sccs = 0usize;

    // Explicit DFS state machine: (vertex, next-edge-offset).
    let mut call: Vec<(VertexId, usize)> = Vec::new();
    for root in 0..n as VertexId {
        if index[root as usize] != UNSET {
            continue;
        }
        call.push((root, 0));
        index[root as usize] = next_index;
        low[root as usize] = next_index;
        next_index += 1;
        stack.push(root);
        on_stack[root as usize] = true;
        while let Some(&mut (v, ref mut ei)) = call.last_mut() {
            if *ei < g.out(v).len() {
                let w = g.out(v)[*ei];
                *ei += 1;
                if index[w as usize] == UNSET {
                    index[w as usize] = next_index;
                    low[w as usize] = next_index;
                    next_index += 1;
                    stack.push(w);
                    on_stack[w as usize] = true;
                    call.push((w, 0));
                } else if on_stack[w as usize] {
                    low[v as usize] = low[v as usize].min(index[w as usize]);
                }
            } else {
                call.pop();
                if let Some(&mut (p, _)) = call.last_mut() {
                    low[p as usize] = low[p as usize].min(low[v as usize]);
                }
                if low[v as usize] == index[v as usize] {
                    // v is an SCC root: pop the component.
                    loop {
                        let w = stack.pop().expect("tarjan stack underflow");
                        on_stack[w as usize] = false;
                        scc_of[w as usize] = num_sccs as VertexId;
                        if w == v {
                            break;
                        }
                    }
                    num_sccs += 1;
                }
            }
        }
    }

    // Build the condensed DAG with deduped edges.
    let mut b = GraphBuilder::new(num_sccs);
    let mut seen = FxHashSet::default();
    for u in 0..n as VertexId {
        let su = scc_of[u as usize];
        for &v in g.out(u) {
            let sv = scc_of[v as usize];
            if su != sv && seen.insert((su, sv)) {
                b.edge(su, sv);
            }
        }
    }
    Condensation {
        scc_of,
        dag: b.build(),
        num_sccs,
    }
}

/// DFS forest orders over a DAG: pre(v) and post(v) (paper §5.4; the yes/no
/// labels are intervals over these orders).
pub struct DfsOrders {
    pub pre: Vec<u32>,
    pub post: Vec<u32>,
}

/// Compute pre/post orders of a DFS forest over `g` (roots in id order).
pub fn dfs_orders(g: &Graph) -> DfsOrders {
    let n = g.num_vertices();
    let mut pre = vec![u32::MAX; n];
    let mut post = vec![u32::MAX; n];
    let mut pre_c = 0u32;
    let mut post_c = 0u32;
    let mut call: Vec<(VertexId, usize)> = Vec::new();
    for root in 0..n as VertexId {
        if pre[root as usize] != u32::MAX {
            continue;
        }
        pre[root as usize] = pre_c;
        pre_c += 1;
        call.push((root, 0));
        while let Some(&mut (v, ref mut ei)) = call.last_mut() {
            if *ei < g.out(v).len() {
                let w = g.out(v)[*ei];
                *ei += 1;
                if pre[w as usize] == u32::MAX {
                    pre[w as usize] = pre_c;
                    pre_c += 1;
                    call.push((w, 0));
                }
            } else {
                post[v as usize] = post_c;
                post_c += 1;
                call.pop();
            }
        }
    }
    DfsOrders { pre, post }
}

/// Serial reachability oracle on any digraph.
pub fn reaches(g: &Graph, s: VertexId, t: VertexId) -> bool {
    if s == t {
        return true;
    }
    let n = g.num_vertices();
    let mut vis = vec![false; n];
    vis[s as usize] = true;
    let mut stack = vec![s];
    while let Some(u) = stack.pop() {
        for &v in g.out(u) {
            if v == t {
                return true;
            }
            if !vis[v as usize] {
                vis[v as usize] = true;
                stack.push(v);
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn cycle_plus_tail() -> Graph {
        // 0 -> 1 -> 2 -> 0 (SCC), 2 -> 3 -> 4
        let mut b = GraphBuilder::new(5);
        b.edge(0, 1);
        b.edge(1, 2);
        b.edge(2, 0);
        b.edge(2, 3);
        b.edge(3, 4);
        b.build()
    }

    #[test]
    fn condense_merges_cycle() {
        let c = condense(&cycle_plus_tail());
        assert_eq!(c.num_sccs, 3);
        assert_eq!(c.scc_of[0], c.scc_of[1]);
        assert_eq!(c.scc_of[1], c.scc_of[2]);
        assert_ne!(c.scc_of[0], c.scc_of[3]);
        // Condensed graph is a DAG: edge count 2 (scc -> 3 -> 4).
        assert_eq!(c.dag.num_edges(), 2);
    }

    #[test]
    fn condensation_preserves_reachability() {
        let g = gen::twitter_like(300, 4, 61);
        let c = condense(&g);
        for (s, t) in gen::random_pairs(300, 25, 62) {
            let want = reaches(&g, s, t);
            let (ss, st) = (c.scc_of[s as usize], c.scc_of[t as usize]);
            let got = ss == st || reaches(&c.dag, ss, st);
            assert_eq!(got, want, "({s},{t})");
        }
    }

    #[test]
    fn condensed_graph_is_acyclic() {
        let g = gen::twitter_like(200, 5, 63);
        let c = condense(&g);
        // Kahn's algorithm must consume every vertex.
        let n = c.dag.num_vertices();
        let mut indeg = vec![0usize; n];
        for u in 0..n as VertexId {
            for &v in c.dag.out(u) {
                indeg[v as usize] += 1;
            }
        }
        let mut queue: Vec<VertexId> = (0..n as VertexId)
            .filter(|&v| indeg[v as usize] == 0)
            .collect();
        let mut seen = 0;
        while let Some(u) = queue.pop() {
            seen += 1;
            for &v in c.dag.out(u) {
                indeg[v as usize] -= 1;
                if indeg[v as usize] == 0 {
                    queue.push(v);
                }
            }
        }
        assert_eq!(seen, n, "condensation must be acyclic");
    }

    #[test]
    fn dfs_orders_are_permutations() {
        let g = gen::webuk_like(500, 20, 3, 64);
        let o = dfs_orders(&g);
        let mut pre = o.pre.clone();
        pre.sort_unstable();
        assert_eq!(pre, (0..500).collect::<Vec<u32>>());
        let mut post = o.post.clone();
        post.sort_unstable();
        assert_eq!(post, (0..500).collect::<Vec<u32>>());
    }

    #[test]
    fn dfs_ancestor_interval_nesting() {
        // In a DFS forest, tree-descendants have nested [pre, post].
        let mut b = GraphBuilder::new(4);
        b.edge(0, 1);
        b.edge(1, 2);
        b.edge(0, 3);
        let g = b.build();
        let o = dfs_orders(&g);
        assert!(o.pre[0] < o.pre[1] && o.post[1] < o.post[0]);
        assert!(o.pre[1] < o.pre[2] && o.post[2] < o.post[1]);
    }
}
