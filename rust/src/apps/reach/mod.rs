//! P2P reachability queries (paper §5.4): SCC condensation, DFS-forest
//! pre/post orders, level / yes / no labels, and the pruned BiBFS query.

pub mod dag;
pub mod labels;
pub mod query;

pub use dag::{condense, Condensation};
pub use labels::{build_labels, ReachLabels};
pub use query::ReachQuery;
