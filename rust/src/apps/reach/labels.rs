//! Level / yes / no label construction (paper §5.4), each as a Pregel-style
//! Quegel job over the condensed DAG.
//!
//! * level ℓ(v): longest hop count from any root (zero in-degree vertex);
//!   if u reaches v then ℓ(u) < ℓ(v).
//! * yes-label [pre(v), max_{u ∈ Out(v)} pre(u)]: yes(v) ⊆ yes(u) ⇒ u
//!   reaches v.
//! * no-label [min_{u ∈ Out(v)} post(u), post(v)]: u reaches v ⇒
//!   no(v) ⊆ no(u) (used contrapositively for pruning).
//!
//! The yes/no jobs come in two variants: the simple multi-update algorithm
//! and the level-aligned one (each vertex broadcasts exactly once, driven
//! by an ℓ_max countdown aggregator) — the paper describes both; the bench
//! compares them as an ablation.

use super::dag::dfs_orders;
use crate::coordinator::Engine;
use crate::graph::{Graph, VertexId};
use crate::network::Cluster;
use crate::vertex::{Ctx, MasterAction, QueryApp};

/// The reachability label set over the DAG.
#[derive(Debug, Clone, Default)]
pub struct ReachLabels {
    /// ℓ(v): longest path length from a root.
    pub level: Vec<u32>,
    /// yes(v) = [pre(v), max pre over Out(v)].
    pub yes: Vec<(u32, u32)>,
    /// no(v) = [min post over Out(v), post(v)].
    pub no: Vec<(u32, u32)>,
}

impl ReachLabels {
    /// Interval containment a ⊆ b.
    #[inline]
    pub fn subsumes(b: (u32, u32), a: (u32, u32)) -> bool {
        b.0 <= a.0 && a.1 <= b.1
    }
}

// ---------------------------------------------------------------------------
// Level job.
// ---------------------------------------------------------------------------

/// Longest-path level computation (paper's Pregel algorithm).
struct LevelJob<'g> {
    g: &'g Graph,
    roots: Vec<VertexId>,
}

impl<'g> QueryApp for LevelJob<'g> {
    type Query = ();
    /// Current level estimate (-1 = unset).
    type VQ = i64;
    /// Proposed level (sender level + 1).
    type Msg = i64;
    type Agg = ();
    type Out = Vec<(VertexId, u32)>;

    fn init_activate(&self, _q: &()) -> Vec<VertexId> {
        self.roots.clone()
    }

    fn init_value(&self, _q: &(), _v: VertexId) -> i64 {
        -1
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, lvl: &mut i64) {
        if ctx.superstep() == 1 {
            *lvl = 0;
            for &u in self.g.out(v) {
                ctx.send(u, 1);
            }
            ctx.vote_halt();
            return;
        }
        let proposed = ctx.msgs().iter().copied().max().unwrap_or(-1);
        if proposed > *lvl {
            *lvl = proposed;
            for &u in self.g.out(v) {
                ctx.send(u, proposed + 1);
            }
        }
        ctx.vote_halt();
    }

    /// Max-combiner: only the largest proposal matters.
    fn combine(&self, into: &mut i64, from: &i64) -> bool {
        *into = (*into).max(*from);
        true
    }

    fn finish(
        &self,
        _q: &(),
        touched: &mut dyn Iterator<Item = (VertexId, &i64)>,
        _agg: &(),
    ) -> Self::Out {
        let mut out = Vec::new();
        for (v, &l) in touched {
            if l >= 0 {
                out.push((v, l as u32));
            }
        }
        out
    }

    fn msg_bytes(&self) -> usize {
        4
    }
}

// ---------------------------------------------------------------------------
// Yes/no label jobs (simple and level-aligned variants).
// ---------------------------------------------------------------------------

/// Which interval endpoint is being propagated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// yes: fold = max over pre-orders.
    YesMax,
    /// no: fold = min over post-orders.
    NoMin,
}

/// ℓ_max countdown aggregator for the level-aligned variants.
#[derive(Debug, Clone, Copy)]
struct Countdown {
    lmax: i64,
}

impl Default for Countdown {
    fn default() -> Self {
        Self { lmax: -1 }
    }
}

/// Backward propagation of max-pre (yes) / min-post (no) along in-edges.
struct BoundJob<'g> {
    g: &'g Graph,
    /// pre(v) or post(v), per mode.
    order: Vec<u32>,
    /// ℓ(v) for the level-aligned variant.
    level: Vec<u32>,
    mode: Mode,
    /// Level-aligned: broadcast exactly once, at ℓ(v)'s countdown turn.
    aligned: bool,
    /// Zero out-degree vertices (the initial activation set).
    sinks: Vec<VertexId>,
}

impl<'g> BoundJob<'g> {
    #[inline]
    fn fold(&self, a: u32, b: u32) -> u32 {
        match self.mode {
            Mode::YesMax => a.max(b),
            Mode::NoMin => a.min(b),
        }
    }
}

impl<'g> QueryApp for BoundJob<'g> {
    type Query = ();
    /// Current bound (max pre / min post over Out(v) ∪ {v}).
    type VQ = u32;
    type Msg = u32;
    type Agg = Countdown;
    type Out = Vec<(VertexId, u32)>;

    fn init_activate(&self, _q: &()) -> Vec<VertexId> {
        self.sinks.clone()
    }

    fn init_value(&self, _q: &(), v: VertexId) -> u32 {
        self.order[v as usize]
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, bound: &mut u32) {
        if self.aligned {
            // Level-aligned: collect at step 1, broadcast at ℓ(v)'s turn.
            if ctx.superstep() == 1 {
                let lvl = self.level[v as usize] as i64;
                ctx.aggregate(|_, a| a.lmax = a.lmax.max(lvl));
                return; // stay active
            }
            for &m in ctx.msgs() {
                *bound = self.fold(*bound, m);
            }
            if self.level[v as usize] as i64 == ctx.agg_prev().lmax {
                for &u in self.g.inn(v) {
                    ctx.send(u, *bound);
                }
                ctx.vote_halt();
            }
            // else: stay active until our level's turn.
            return;
        }
        // Simple variant: broadcast on every improvement.
        if ctx.superstep() == 1 {
            for &u in self.g.inn(v) {
                ctx.send(u, *bound);
            }
            ctx.vote_halt();
            return;
        }
        let mut improved = false;
        for &m in ctx.msgs() {
            let f = self.fold(*bound, m);
            if f != *bound {
                *bound = f;
                improved = true;
            }
        }
        if improved {
            for &u in self.g.inn(v) {
                ctx.send(u, *bound);
            }
        }
        ctx.vote_halt();
    }

    fn combine(&self, into: &mut u32, from: &u32) -> bool {
        *into = self.fold(*into, *from);
        true
    }

    /// The countdown collects a max over levels; -1 is the identity.
    fn agg_merge(&self, into: &mut Countdown, from: &Countdown) {
        into.lmax = into.lmax.max(from.lmax);
    }

    fn master_step(
        &self,
        _q: &(),
        step: u64,
        prev: &Countdown,
        cur: &mut Countdown,
    ) -> MasterAction {
        if !self.aligned {
            return MasterAction::Continue;
        }
        if step == 1 {
            if cur.lmax < 0 {
                return MasterAction::Terminate;
            }
            return MasterAction::Continue;
        }
        cur.lmax = prev.lmax - 1;
        if cur.lmax < 0 {
            return MasterAction::Terminate;
        }
        MasterAction::Continue
    }

    fn finish(
        &self,
        _q: &(),
        touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &Countdown,
    ) -> Self::Out {
        let mut out = Vec::new();
        for (v, &b) in touched {
            out.push((v, b));
        }
        out
    }

    fn msg_bytes(&self) -> usize {
        4
    }
}

/// Per-label-type indexing statistics (Table 11b rows).
#[derive(Debug, Clone, Default)]
pub struct LabelStats {
    pub level_time: f64,
    pub yes_time: f64,
    pub no_time: f64,
    /// Supersteps of the level job (paper: 2793 on WebUK vs 23 on Twitter).
    pub level_supersteps: u64,
}

/// Build all three label sets over the DAG. `dag` must have in-edges.
/// `aligned` selects the level-aligned yes/no variants.
pub fn build_labels(dag: &Graph, cluster: &Cluster, aligned: bool) -> (ReachLabels, LabelStats) {
    assert!(dag.has_in_edges(), "build_labels requires ensure_in_edges()");
    let n = dag.num_vertices();
    let mut stats = LabelStats::default();

    // --- Level job.
    let roots: Vec<VertexId> = (0..n as VertexId)
        .filter(|&v| dag.in_degree(v) == 0)
        .collect();
    let mut eng = Engine::new(LevelJob { g: dag, roots }, cluster.clone(), n);
    let res = eng.run_one(());
    stats.level_time = eng.sim_time();
    stats.level_supersteps = res.stats.supersteps;
    let mut level = vec![0u32; n];
    for (v, l) in res.out {
        level[v as usize] = l;
    }

    // --- DFS orders (offline preprocessing per the paper).
    let orders = dfs_orders(dag);
    let sinks: Vec<VertexId> = (0..n as VertexId)
        .filter(|&v| dag.out_degree(v) == 0)
        .collect();

    // --- Yes job (max pre over Out(v)).
    let mut eng = Engine::new(
        BoundJob {
            g: dag,
            order: orders.pre.clone(),
            level: level.clone(),
            mode: Mode::YesMax,
            aligned,
            sinks: sinks.clone(),
        },
        cluster.clone(),
        n,
    );
    let res = eng.run_one(());
    stats.yes_time = eng.sim_time();
    let mut max_pre = orders.pre.clone();
    for (v, b) in res.out {
        max_pre[v as usize] = b;
    }
    let yes: Vec<(u32, u32)> = (0..n).map(|v| (orders.pre[v], max_pre[v])).collect();

    // --- No job (min post over Out(v)).
    let mut eng = Engine::new(
        BoundJob {
            g: dag,
            order: orders.post.clone(),
            level: level.clone(),
            mode: Mode::NoMin,
            aligned,
            sinks,
        },
        cluster.clone(),
        n,
    );
    let res = eng.run_one(());
    stats.no_time = eng.sim_time();
    let mut min_post = orders.post.clone();
    for (v, b) in res.out {
        min_post[v as usize] = b;
    }
    let no: Vec<(u32, u32)> = (0..n).map(|v| (min_post[v], orders.post[v])).collect();

    (ReachLabels { level, yes, no }, stats)
}

#[cfg(test)]
mod tests {
    use super::super::dag::{condense, reaches};
    use super::*;
    use crate::graph::gen;

    fn dag_fixture(seed: u64) -> Graph {
        let g = gen::web_cyclic(600, 20, 3, seed);
        let mut dag = condense(&g).dag;
        dag.ensure_in_edges();
        dag
    }

    #[test]
    fn level_respects_reachability() {
        let dag = dag_fixture(71);
        let (labels, _) = build_labels(&dag, &Cluster::new(4), false);
        for (s, t) in gen::random_pairs(dag.num_vertices(), 40, 72) {
            if reaches(&dag, s, t) && s != t {
                assert!(
                    labels.level[s as usize] < labels.level[t as usize],
                    "u reaches v ⇒ ℓ(u) < ℓ(v) for ({s},{t})"
                );
            }
        }
    }

    #[test]
    fn yes_label_soundness() {
        // yes(v) ⊆ yes(u) ⇒ u reaches v.
        let dag = dag_fixture(73);
        let (labels, _) = build_labels(&dag, &Cluster::new(4), false);
        for (u, v) in gen::random_pairs(dag.num_vertices(), 60, 74) {
            if ReachLabels::subsumes(labels.yes[u as usize], labels.yes[v as usize]) {
                assert!(reaches(&dag, u, v), "yes-label claims {u} reaches {v}");
            }
        }
    }

    #[test]
    fn no_label_soundness() {
        // u reaches v ⇒ no(v) ⊆ no(u).
        let dag = dag_fixture(75);
        let (labels, _) = build_labels(&dag, &Cluster::new(4), false);
        for (u, v) in gen::random_pairs(dag.num_vertices(), 40, 76) {
            if reaches(&dag, u, v) {
                assert!(
                    ReachLabels::subsumes(labels.no[u as usize], labels.no[v as usize]),
                    "({u},{v}) reachable but no(v) ⊄ no(u)"
                );
            }
        }
    }

    #[test]
    fn aligned_and_simple_variants_agree() {
        let dag = dag_fixture(77);
        let (a, _) = build_labels(&dag, &Cluster::new(4), false);
        let (b, _) = build_labels(&dag, &Cluster::new(4), true);
        assert_eq!(a.level, b.level);
        assert_eq!(a.yes, b.yes);
        assert_eq!(a.no, b.no);
    }

    #[test]
    fn deep_dag_has_many_level_supersteps() {
        // WebUK-like layered DAGs need many more supersteps than flat ones
        // (paper: 2793 vs 23).
        let mut deep = gen::webuk_like(2_000, 100, 3, 78);
        deep.ensure_in_edges();
        let (_, s_deep) = build_labels(&deep, &Cluster::new(4), false);
        let flat = dag_fixture(79);
        let (_, s_flat) = build_labels(&flat, &Cluster::new(4), false);
        assert!(
            s_deep.level_supersteps > 2 * s_flat.level_supersteps,
            "deep {} !> 2x flat {}",
            s_deep.level_supersteps,
            s_flat.level_supersteps
        );
    }
}
