//! P2P reachability queries over the condensed DAG with label pruning
//! (paper §5.4): bidirectional BFS where every activated vertex is checked
//! against the yes-label (instant positive answer), the level label and the
//! no-label (pruning directions that cannot reach the target).

use super::dag::Condensation;
use super::labels::ReachLabels;
use crate::graph::{Graph, VertexId};
use crate::vertex::{Ctx, MasterAction, QueryApp};

/// Direction bits.
const FWD: u8 = 1;
const BWD: u8 = 2;

/// Aggregator: answer flag + per-direction message counts.
#[derive(Debug, Clone, Default)]
pub struct ReachAgg {
    /// 0 = unknown, 1 = reachable, 2 = exhausted (unreachable).
    pub verdict: u8,
    pub fwd_sent: u64,
    pub bwd_sent: u64,
}

/// Reachability query app over the DAG. Query = (s_dag, t_dag).
pub struct ReachQuery<'g, 'l> {
    dag: &'g Graph,
    labels: &'l ReachLabels,
}

impl<'g, 'l> ReachQuery<'g, 'l> {
    pub fn new(dag: &'g Graph, labels: &'l ReachLabels) -> Self {
        assert!(dag.has_in_edges(), "ReachQuery needs in-adjacency");
        Self { dag, labels }
    }

    /// Map an original-graph query to DAG vertices (the paper's
    /// init_activate index lookup through the v → SCC map).
    pub fn to_dag_query(cond: &Condensation, s: VertexId, t: VertexId) -> (VertexId, VertexId) {
        (cond.scc_of[s as usize], cond.scc_of[t as usize])
    }

    /// Label-only fast path: Some(answer) if labels decide without search.
    pub fn label_only(&self, s: VertexId, t: VertexId) -> Option<bool> {
        if s == t {
            return Some(true);
        }
        let l = self.labels;
        if ReachLabels::subsumes(l.yes[s as usize], l.yes[t as usize]) {
            return Some(true);
        }
        if l.level[s as usize] >= l.level[t as usize] {
            return Some(false);
        }
        if !ReachLabels::subsumes(l.no[s as usize], l.no[t as usize]) {
            return Some(false);
        }
        None
    }
}

/// Per-vertex state: which directions have reached this vertex.
pub type ReachState = u8;

impl<'g, 'l> QueryApp for ReachQuery<'g, 'l> {
    type Query = (VertexId, VertexId);
    type VQ = ReachState;
    type Msg = u8;
    type Agg = ReachAgg;
    type Out = bool;

    fn init_activate(&self, q: &(VertexId, VertexId)) -> Vec<VertexId> {
        if q.0 == q.1 {
            vec![q.0]
        } else {
            vec![q.0, q.1]
        }
    }

    fn init_value(&self, q: &(VertexId, VertexId), v: VertexId) -> ReachState {
        let mut m = 0;
        if v == q.0 {
            m |= FWD;
        }
        if v == q.1 {
            m |= BWD;
        }
        m
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, st: &mut ReachState) {
        let (s, t) = *ctx.query();
        let l = self.labels;
        if ctx.superstep() == 1 {
            // Label-only resolution before any traversal.
            if let Some(ans) = self.label_only(s, t) {
                if v == s {
                    ctx.aggregate(|_, a| a.verdict = if ans { 1 } else { 2 });
                    ctx.force_terminate();
                }
                ctx.vote_halt();
                return;
            }
            if v == s {
                for &u in self.dag.out(v) {
                    ctx.send(u, FWD);
                }
                let n = self.dag.out(v).len() as u64;
                ctx.aggregate(|_, a| a.fwd_sent += n);
            }
            if v == t {
                for &u in self.dag.inn(v) {
                    ctx.send(u, BWD);
                }
                let n = self.dag.inn(v).len() as u64;
                ctx.aggregate(|_, a| a.bwd_sent += n);
            }
            ctx.vote_halt();
            return;
        }
        let mut mask = 0u8;
        for &m in ctx.msgs() {
            mask |= m;
        }
        let newly_fwd = mask & FWD != 0 && *st & FWD == 0;
        let newly_bwd = mask & BWD != 0 && *st & BWD == 0;
        *st |= mask;
        if *st & FWD != 0 && *st & BWD != 0 {
            // Meeting point: s reaches v and v reaches t.
            ctx.aggregate(|_, a| a.verdict = 1);
            ctx.force_terminate();
            ctx.vote_halt();
            return;
        }
        if newly_fwd {
            // Forward wavefront: s reaches v. Label checks against t.
            if ReachLabels::subsumes(l.yes[v as usize], l.yes[t as usize]) {
                // v reaches t via yes-label ⇒ s reaches t.
                ctx.aggregate(|_, a| a.verdict = 1);
                ctx.force_terminate();
                ctx.vote_halt();
                return;
            }
            let prune = l.level[v as usize] >= l.level[t as usize]
                || !ReachLabels::subsumes(l.no[v as usize], l.no[t as usize]);
            if !prune {
                for &u in self.dag.out(v) {
                    ctx.send(u, FWD);
                }
                let n = self.dag.out(v).len() as u64;
                ctx.aggregate(|_, a| a.fwd_sent += n);
            }
        }
        if newly_bwd {
            // Backward wavefront: v reaches t. Label checks against s.
            if ReachLabels::subsumes(l.yes[s as usize], l.yes[v as usize]) {
                ctx.aggregate(|_, a| a.verdict = 1);
                ctx.force_terminate();
                ctx.vote_halt();
                return;
            }
            let prune = l.level[s as usize] >= l.level[v as usize]
                || !ReachLabels::subsumes(l.no[s as usize], l.no[v as usize]);
            if !prune {
                for &u in self.dag.inn(v) {
                    ctx.send(u, BWD);
                }
                let n = self.dag.inn(v).len() as u64;
                ctx.aggregate(|_, a| a.bwd_sent += n);
            }
        }
        ctx.vote_halt();
    }

    fn combine(&self, into: &mut u8, from: &u8) -> bool {
        *into |= *from;
        true
    }

    fn agg_merge(&self, into: &mut ReachAgg, from: &ReachAgg) {
        into.verdict = into.verdict.max(from.verdict);
        into.fwd_sent += from.fwd_sent;
        into.bwd_sent += from.bwd_sent;
    }

    fn master_step(
        &self,
        _q: &(VertexId, VertexId),
        step: u64,
        prev: &ReachAgg,
        agg: &mut ReachAgg,
    ) -> MasterAction {
        if prev.verdict == 1 {
            agg.verdict = 1;
        }
        if agg.verdict != 0 {
            return MasterAction::Terminate;
        }
        if step >= 1 && (agg.fwd_sent == 0 || agg.bwd_sent == 0) {
            agg.verdict = 2;
            return MasterAction::Terminate;
        }
        agg.fwd_sent = 0;
        agg.bwd_sent = 0;
        MasterAction::Continue
    }

    fn finish(
        &self,
        _q: &(VertexId, VertexId),
        _touched: &mut dyn Iterator<Item = (VertexId, &ReachState)>,
        agg: &ReachAgg,
    ) -> bool {
        agg.verdict == 1
    }

    fn msg_bytes(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::super::dag::{condense, reaches};
    use super::super::labels::build_labels;
    use super::*;
    use crate::coordinator::Engine;
    use crate::graph::gen;
    use crate::network::Cluster;

    fn setup(seed: u64) -> (Graph, Condensation, ReachLabels) {
        let g = gen::web_cyclic(600, 20, 3, seed);
        let cond = condense(&g);
        let mut dag = cond.dag.clone();
        dag.ensure_in_edges();
        let (labels, _) = build_labels(&dag, &Cluster::new(4), true);
        (g, Condensation { dag, ..cond }, labels)
    }

    #[test]
    fn indexed_reachability_matches_oracle() {
        let (g, cond, labels) = setup(81);
        let app = ReachQuery::new(&cond.dag, &labels);
        let mut eng = Engine::new(app, Cluster::new(4), cond.num_sccs);
        for (s, t) in gen::random_pairs(g.num_vertices(), 40, 82) {
            let want = reaches(&g, s, t);
            let dq = ReachQuery::to_dag_query(&cond, s, t);
            let got = eng.run_one(dq).out;
            assert_eq!(got, want, "({s},{t}) dag {dq:?}");
        }
    }

    #[test]
    fn same_scc_is_reachable() {
        let (g, cond, labels) = setup(83);
        let _ = g;
        let app = ReachQuery::new(&cond.dag, &labels);
        let mut eng = Engine::new(app, Cluster::new(2), cond.num_sccs);
        assert!(eng.run_one((5, 5)).out);
    }

    #[test]
    fn label_pruning_reduces_access() {
        let (g, cond, labels) = setup(85);
        // Unpruned bidirectional search (empty labels = no pruning power):
        // give it degenerate labels that never prune nor shortcut.
        let n = cond.num_sccs;
        let no_labels = ReachLabels {
            // level[v] = 0 except level of every vertex unchecked: use
            // strictly increasing dummy levels so level pruning never fires,
            level: (0..n as u32).map(|v| v % 1).collect(), // all zero
            yes: (0..n as u32).map(|v| (v, v)).collect(),
            no: vec![(0, u32::MAX); n],
        };
        // With all-zero levels the rule ℓ(s) >= ℓ(t) would *always* prune;
        // instead emulate "no pruning" by monotone levels along edges:
        // recompute unpruned via labels from build (level only cannot be
        // faked simply) — so just compare touched counts with and without
        // yes/no shortcuts by zeroing yes/no power only.
        let (real_labels, _) = (labels.clone(), ());
        let weak_labels = ReachLabels {
            level: real_labels.level.clone(),
            yes: no_labels.yes,
            no: vec![(0, u32::MAX); n],
        };
        let queries = gen::random_pairs(g.num_vertices(), 15, 86);
        let mut touched_real = 0u64;
        let mut touched_weak = 0u64;
        for &(s, t) in &queries {
            let dq = ReachQuery::to_dag_query(&cond, s, t);
            let mut e1 = Engine::new(ReachQuery::new(&cond.dag, &real_labels), Cluster::new(4), n);
            let r1 = e1.run_one(dq);
            let mut e2 = Engine::new(ReachQuery::new(&cond.dag, &weak_labels), Cluster::new(4), n);
            let r2 = e2.run_one(dq);
            assert_eq!(r1.out, r2.out, "pruning must not change answers");
            touched_real += r1.stats.touched;
            touched_weak += r2.stats.touched;
        }
        assert!(
            touched_real <= touched_weak,
            "labels must not increase access: {touched_real} > {touched_weak}"
        );
    }
}
