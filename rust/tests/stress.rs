//! Concurrency stress: repeated fixed-seed runs of interleaved submission
//! on the *threaded* engine (threads = 4), asserting the per-query stats
//! invariants the scheduler must uphold no matter how lanes are scheduled
//! onto OS threads.

use quegel::apps::ppsp::{oracle, Bfs, UNREACHED};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::network::Cluster;

const REPS: u64 = 50;
const CAPACITY: usize = 4;

#[test]
fn interleaved_submission_invariants_hold_across_50_reps() {
    for rep in 0..REPS {
        let seed = 7000 + rep * 3;
        let n = 400 + (rep as usize % 5) * 50;
        let g = gen::twitter_like(n, 4, seed);
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), n)
            .capacity(CAPACITY)
            .threads(4);

        let q1 = gen::random_pairs(n, 4, seed + 1);
        let q2 = gen::random_pairs(n, 4, seed + 2);
        let mut submitted = 0usize;
        for &q in &q1 {
            eng.submit(q);
            submitted += 1;
        }
        // Run a couple of super-rounds, then add more queries mid-flight.
        eng.super_round();
        eng.super_round();
        for &q in &q2 {
            eng.submit(q);
            submitted += 1;
        }
        eng.run_until_idle();

        // Result count equals submissions; capacity never exceeded.
        assert_eq!(eng.results().len(), submitted, "rep {rep}");
        assert!(
            eng.metrics().peak_inflight <= CAPACITY,
            "rep {rep}: peak {} > C = {CAPACITY}",
            eng.metrics().peak_inflight
        );

        for r in eng.results() {
            let s = &r.stats;
            // Scheduling timeline is monotone.
            assert!(
                s.submitted_at <= s.started_at,
                "rep {rep} q{}: submitted {} > started {}",
                s.qid,
                s.submitted_at,
                s.started_at
            );
            assert!(
                s.started_at <= s.finished_at,
                "rep {rep} q{}: started {} > finished {}",
                s.qid,
                s.started_at,
                s.finished_at
            );
            // Lazy VQ-data can never exceed the vertex universe.
            assert!(
                s.touched <= n as u64,
                "rep {rep} q{}: touched {} > |V| = {n}",
                s.qid,
                s.touched
            );
            // Answers stay correct under interleaving + threading.
            let (qs, qt) = if (r.qid as usize) < q1.len() {
                q1[r.qid as usize]
            } else {
                q2[r.qid as usize - q1.len()]
            };
            let want = oracle::bfs_dist(&g, qs, qt);
            assert_eq!(
                r.out,
                (want != UNREACHED).then_some(want),
                "rep {rep} query ({qs},{qt})"
            );
        }
    }
}
