//! Concurrency stress: repeated fixed-seed runs of interleaved submission
//! on the *threaded* engine (threads = 4), asserting the per-query stats
//! invariants the scheduler must uphold no matter how lanes are scheduled
//! onto OS threads — plus the work-stealing skew stress: one
//! pathologically heavy lane must be absorbed by steals without changing
//! a single output bit.

use quegel::apps::ppsp::{oracle, Bfs, UNREACHED};
use quegel::coordinator::{Engine, Sched};
use quegel::graph::gen;
use quegel::network::Cluster;

const REPS: u64 = 50;
const CAPACITY: usize = 4;

/// Work-stealing under pathological lane skew. `hub_concentrated` with
/// stride = 16 puts every high-degree vertex (64-edge fanout each, vs a
/// background degree of ~5) on worker 0 of a 16-worker cluster, so lane 0
/// carries an order of magnitude more compute than any other lane. At
/// `threads = 8` the pool distributes the 16 lane jobs two per deque:
/// the deque that owns lane 0 cannot reach its second lane until the hub
/// lane finishes, so some idle thread must steal it — and with per-query
/// fold jobs and per-destination exchange jobs on top, every super-round
/// offers steal opportunities.
///
/// Asserts (a) outputs are bit-identical to the fully serial `threads = 1`
/// run, and (b) the steal path actually engaged (`metrics.steals() > 0`).
/// Steal counts depend on OS scheduling, so (b) is given three attempts
/// before the steal path is declared dead; (a) must hold on every attempt.
#[test]
fn work_stealing_absorbs_pathological_lane_skew() {
    const N: usize = 8_000;
    const WORKERS: usize = 16;
    let g = gen::hub_concentrated(N, WORKERS, 64, 2, 4242);
    let queries = gen::random_pairs(N, 24, 4243);
    let run = |threads: usize| {
        // Explicitly a WORK-STEALING test: must not silently flip to the
        // static baseline under CI's QUEGEL_TEST_SCHED=static matrix lane
        // (static chunks only steal on a startup race, so the steals > 0
        // assertion would become a lottery there).
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(WORKERS), N)
            .capacity(8)
            .threads(threads)
            .scheduler(Sched::Stealing);
        let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
        eng.run_until_idle();
        let outs: Vec<Option<u32>> = ids
            .iter()
            .map(|id| {
                eng.results()
                    .iter()
                    .find(|r| r.qid == *id)
                    .expect("query completed")
                    .out
            })
            .collect();
        (outs, eng.metrics().steals(), eng.metrics().max_lane_imbalance)
    };
    let (serial, serial_steals, imbalance) = run(1);
    assert_eq!(serial_steals, 0, "threads = 1 must never hit the pool");
    assert!(
        imbalance > 4.0,
        "partition must be pathologically skewed for this test to bite, \
         got lane imbalance {imbalance:.2}"
    );
    let mut steals = 0;
    for _ in 0..3 {
        let (outs, s, _) = run(8);
        assert_eq!(outs, serial, "stealing changed query outputs");
        steals = s;
        if steals > 0 {
            break;
        }
    }
    assert!(
        steals > 0,
        "a heavy-lane batch at threads = 8 never stole a single job"
    );
}

#[test]
fn interleaved_submission_invariants_hold_across_50_reps() {
    for rep in 0..REPS {
        let seed = 7000 + rep * 3;
        let n = 400 + (rep as usize % 5) * 50;
        let g = gen::twitter_like(n, 4, seed);
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), n)
            .capacity(CAPACITY)
            .threads(4);

        let q1 = gen::random_pairs(n, 4, seed + 1);
        let q2 = gen::random_pairs(n, 4, seed + 2);
        let mut submitted = 0usize;
        for &q in &q1 {
            eng.submit(q);
            submitted += 1;
        }
        // Run a couple of super-rounds, then add more queries mid-flight.
        eng.super_round();
        eng.super_round();
        for &q in &q2 {
            eng.submit(q);
            submitted += 1;
        }
        eng.run_until_idle();

        // Result count equals submissions; capacity never exceeded.
        assert_eq!(eng.results().len(), submitted, "rep {rep}");
        assert!(
            eng.metrics().peak_inflight <= CAPACITY,
            "rep {rep}: peak {} > C = {CAPACITY}",
            eng.metrics().peak_inflight
        );

        for r in eng.results() {
            let s = &r.stats;
            // Scheduling timeline is monotone.
            assert!(
                s.submitted_at <= s.started_at,
                "rep {rep} q{}: submitted {} > started {}",
                s.qid,
                s.submitted_at,
                s.started_at
            );
            assert!(
                s.started_at <= s.finished_at,
                "rep {rep} q{}: started {} > finished {}",
                s.qid,
                s.started_at,
                s.finished_at
            );
            // Lazy VQ-data can never exceed the vertex universe.
            assert!(
                s.touched <= n as u64,
                "rep {rep} q{}: touched {} > |V| = {n}",
                s.qid,
                s.touched
            );
            // Answers stay correct under interleaving + threading.
            let (qs, qt) = if (r.qid as usize) < q1.len() {
                q1[r.qid as usize]
            } else {
                q2[r.qid as usize - q1.len()]
            };
            let want = oracle::bfs_dist(&g, qs, qt);
            assert_eq!(
                r.out,
                (want != UNREACHED).then_some(want),
                "rep {rep} query ({qs},{qt})"
            );
        }
    }
}
