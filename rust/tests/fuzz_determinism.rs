//! Randomized determinism fuzzer for the sub-lane + edge-level split
//! engine.
//!
//! The hand-written determinism suite sweeps a fixed grid; this fuzzer
//! drives the same guarantee through ~100 *random* corners: a seeded
//! `util::Rng` generates random graphs (five structural families,
//! including the pathological mega-hub and mono-hub) × random query
//! batches × random engine configurations `{threads, workers, capacity,
//! Sched, Split, EdgeSplit, Pipeline, Layout}`, and every
//! configuration's `QueryResult::out` vector must be bit-identical to
//! the serial reference run (`threads = 1`, static scheduler, all
//! splitting off, barrier rounds, the hashed-map layout). Each case
//! additionally runs one **edge-threshold-1 forcing configuration**
//! (`EdgeSplit::MaxFanout(1)` + a tiny vertex-split threshold), which
//! parks every multi-message outbox and dices it into single-edge
//! ranges — the most adversarial exercise of the park/range/fold replay
//! there is — one **pipeline forcing configuration** (`Pipeline::On`,
//! splitting off, 4 threads) whose ready-driven rounds are guaranteed to
//! engage, and one **flat-layout forcing configuration**
//! (`Layout::Flat` + stealing + both splits armed) whose arena stores
//! and columnar staging are guaranteed to engage (asserted at the end
//! via the `staging_bytes_peak` gauge, which only the flat path ever
//! moves). On a mismatch the failing case seed and configuration are
//! printed, so any regression reproduces with a one-line test.
//!
//! Since the serving layer, the random configuration also draws the
//! `Admit` knob (random static capacities and the adaptive planner —
//! inert for apps that flag nothing heavy, admission-width-throttling
//! otherwise), and each case runs one **admission forcing
//! configuration**: a BFS clone that flags EVERY query heavy under
//! `Admit::Adaptive`, so the whole batch squeezes through the reserved
//! capacity slice — deferrals are counted and asserted at the end, and
//! the outputs must still be bit-identical to the serial reference.
//!
//! `QUEGEL_BENCH_SMOKE=1` shrinks the case count for the CI smoke lane;
//! `QUEGEL_FUZZ_CASES=N` overrides it outright (the nightly deep-fuzz CI
//! lane runs 1000). The split thresholds are deliberately drawn small, so
//! both the vertex-range and the edge-range paths engage even on
//! fuzz-sized graphs — asserted at the end, to make sure the fuzz can
//! never silently degenerate into testing the unsplit paths.

use quegel::apps::ppsp::{Bfs, BiBfs, UNREACHED};
use quegel::coordinator::{Admit, EdgeSplit, Engine, Layout, Pipeline, Sched, Split};
use quegel::graph::{gen, Graph, VertexId};
use quegel::network::Cluster;
use quegel::util::{env_flag, env_u64, env_usize, Rng};
use quegel::vertex::{Ctx, QueryApp};

/// One random engine configuration of a fuzz case.
#[derive(Debug, Clone, Copy)]
struct Config {
    threads: usize,
    workers: usize,
    capacity: usize,
    sched: Sched,
    split: Split,
    edge: EdgeSplit,
    pipeline: Pipeline,
    layout: Layout,
    admit: Admit,
}

fn random_config(rng: &mut Rng) -> Config {
    let sched = if rng.chance(0.3) {
        Sched::Static
    } else {
        Sched::Stealing
    };
    let split = match rng.below(4) {
        0 => Split::Off,
        1 => Split::Adaptive,
        // Small fixed thresholds, so fuzz-sized frontiers really split.
        2 => Split::MaxTaskVertices(1 + rng.below_usize(48)),
        _ => Split::MaxTaskVertices(64 + rng.below_usize(256)),
    };
    let edge = match rng.below(4) {
        0 => EdgeSplit::Off,
        1 => EdgeSplit::Adaptive,
        // Tiny fanout thresholds, so ordinary-degree vertices park too
        // (including ranges of a single edge).
        2 => EdgeSplit::MaxFanout(1 + rng.below_usize(8)),
        _ => EdgeSplit::MaxFanout(32 + rng.below_usize(256)),
    };
    // The pipelined path only engages when splitting stays disarmed, so a
    // random draw here mostly tests that Pipeline::On *degrades* to the
    // barrier path correctly; the dedicated forcing config below is what
    // guarantees the ready-driven rounds themselves run every case.
    let pipeline = if rng.chance(0.5) {
        Pipeline::On
    } else {
        Pipeline::Off
    };
    let layout = if rng.chance(0.5) {
        Layout::Flat
    } else {
        Layout::Hashed
    };
    // For apps that flag nothing heavy, Adaptive degenerates to
    // Static(capacity); small static payloads throttle the admission
    // width below the capacity — either way the answers must not move.
    let admit = if rng.chance(0.5) {
        Admit::Adaptive
    } else {
        Admit::Static(1 + rng.below_usize(8))
    };
    Config {
        threads: [2, 3, 4, 8][rng.below_usize(4)],
        workers: 1 + rng.below_usize(8),
        capacity: [1, 2, 8][rng.below_usize(3)],
        sched,
        split,
        edge,
        pipeline,
        layout,
        admit,
    }
}

/// Random graph from one of five structural families. Returns the graph
/// and a short description for failure messages.
fn random_graph(rng: &mut Rng, seed: u64) -> (Graph, String) {
    let n = 300 + rng.below_usize(900);
    match rng.below(5) {
        0 => {
            let deg = 3 + rng.below_usize(5);
            (
                gen::twitter_like(n, deg, seed),
                format!("twitter_like({n}, {deg}, {seed})"),
            )
        }
        1 => {
            let hub = 8 + rng.below_usize(24);
            let base = 2 + rng.below_usize(4);
            (
                gen::hub_concentrated(n, 8, hub, base, seed),
                format!("hub_concentrated({n}, 8, {hub}, {base}, {seed})"),
            )
        }
        2 => {
            let spoke = 3 + rng.below_usize(8);
            (
                gen::mega_hub(n, 8, spoke, seed),
                format!("mega_hub({n}, 8, {spoke}, {seed})"),
            )
        }
        3 => {
            let spoke = 1 + rng.below_usize(4);
            (
                gen::mono_hub(n, spoke, seed),
                format!("mono_hub({n}, {spoke}, {seed})"),
            )
        }
        _ => {
            let layers = 5 + rng.below_usize(15);
            let deg = 2 + rng.below_usize(4);
            (
                gen::webuk_like(n, layers, deg, seed),
                format!("webuk_like({n}, {layers}, {deg}, {seed})"),
            )
        }
    }
}

/// Which split machinery a run engaged, so the fuzzer can prove it never
/// degenerates into testing only the unsplit paths.
struct Engaged {
    subjobs: bool,
    edge_ranges: bool,
    pipelined: bool,
    flat: bool,
    deferred: bool,
}

/// BFS with every query flagged heavy — the admission forcing app. Same
/// compute as the library's [`Bfs`] (so outputs compare equal to the
/// serial reference of either PPSP app), but under `Admit::Adaptive` the
/// whole batch is confined to the reserved capacity slice and deferrals
/// are guaranteed whenever the batch outnumbers it.
struct HeavyBfs<'g> {
    g: &'g Graph,
}

impl<'g> QueryApp for HeavyBfs<'g> {
    type Query = (u32, u32);
    type VQ = u32;
    type Msg = ();
    type Agg = ();
    type Out = Option<u32>;

    fn is_heavy(&self, _q: &(u32, u32)) -> bool {
        true
    }

    fn init_activate(&self, q: &(u32, u32)) -> Vec<VertexId> {
        vec![q.0]
    }

    fn init_value(&self, q: &(u32, u32), v: VertexId) -> u32 {
        if v == q.0 {
            0
        } else {
            UNREACHED
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, d: &mut u32) {
        let step = ctx.superstep();
        let (_, t) = *ctx.query();
        if step == 1 {
            if v == t {
                ctx.force_terminate();
            }
            for &u in self.g.out(v) {
                ctx.send(u, ());
            }
            ctx.vote_halt();
            return;
        }
        if *d == UNREACHED {
            *d = (step - 1) as u32;
            if v == t {
                ctx.force_terminate();
            } else {
                for &u in self.g.out(v) {
                    ctx.send(u, ());
                }
            }
        }
        ctx.vote_halt();
    }

    fn combine(&self, _into: &mut (), _from: &()) -> bool {
        true
    }

    fn finish(
        &self,
        q: &(u32, u32),
        touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &(),
    ) -> Option<u32> {
        let t = q.1;
        for (v, &d) in touched {
            if v == t && d != UNREACHED {
                return Some(d);
            }
        }
        None
    }
}

/// Run one batch under one configuration, returning outputs in submission
/// order plus which split paths engaged.
fn run_batch<A, F>(mk: F, n: usize, queries: &[A::Query], cfg: Config) -> (Vec<A::Out>, Engaged)
where
    A: QueryApp,
    A::Out: Clone,
    F: FnOnce() -> A,
{
    let mut eng = Engine::new(mk(), Cluster::new(cfg.workers), n)
        .capacity(cfg.capacity)
        .threads(cfg.threads)
        .scheduler(cfg.sched)
        .split(cfg.split)
        .edge_split(cfg.edge)
        .pipeline(cfg.pipeline)
        .layout(cfg.layout)
        .admit(cfg.admit);
    let ids: Vec<_> = queries.iter().map(|q| eng.submit(q.clone())).collect();
    eng.run_until_idle();
    let outs = ids
        .iter()
        .map(|id| {
            eng.results()
                .iter()
                .find(|r| r.qid == *id)
                .expect("query completed")
                .out
                .clone()
        })
        .collect();
    let engaged = Engaged {
        subjobs: eng.metrics().subjobs_executed > 0,
        edge_ranges: eng.metrics().edge_ranges_split > 0,
        pipelined: eng.metrics().pipelined_rounds > 0,
        flat: eng.metrics().staging_bytes_peak > 0,
        deferred: eng.metrics().admit_deferrals > 0,
    };
    (outs, engaged)
}

#[test]
fn randomized_matrix_is_bit_identical_to_serial() {
    // QUEGEL_FUZZ_SEED picks a different deterministic case universe per
    // run (the nightly CI matrix fans out over seeds, so its legs cover
    // DISTINCT cases instead of repeating one batch); the default keeps
    // local and PR runs reproducible.
    let master_seed = env_u64("QUEGEL_FUZZ_SEED").unwrap_or(0x5eed_f022);
    let smoke = env_flag("QUEGEL_BENCH_SMOKE");
    let cases = env_usize("QUEGEL_FUZZ_CASES").unwrap_or(if smoke { 12 } else { 100 });
    let configs_per_case = 3;
    // The reference also pins the hashed-map layout, so every flat-layout
    // draw below is compared against the original stores.
    let serial = Config {
        threads: 1,
        workers: 4,
        capacity: 4,
        sched: Sched::Static,
        split: Split::Off,
        edge: EdgeSplit::Off,
        pipeline: Pipeline::Off,
        layout: Layout::Hashed,
        admit: Admit::Static(4),
    };
    // The edge-threshold-1 forcing leg: every outbox of 2+ messages is
    // parked and diced into single-edge ranges, and a tiny vertex
    // threshold keeps the vertex split in the mix, so the two replay
    // pipelines compose.
    let forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::MaxTaskVertices(5),
        edge: EdgeSplit::MaxFanout(1),
        pipeline: Pipeline::Off,
        layout: Layout::Hashed,
        admit: Admit::Static(8),
    };
    // The pipeline forcing leg: splitting stays off and threads > 1, so
    // every super-round takes the ready-driven per-(query, worker) path —
    // asserted below, per run, so the fuzz can never silently stop
    // exercising it.
    let pipe_forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::Off,
        edge: EdgeSplit::Off,
        pipeline: Pipeline::On,
        layout: Layout::Hashed,
        admit: Admit::Static(8),
    };
    // The flat-layout forcing leg: arena stores + columnar staging under
    // stealing with BOTH splits armed, so the flat replay pipelines (the
    // ordered sub-buffer and edge-range absorption into flat columns)
    // compose every case; engagement is proved per run via the
    // staging_bytes_peak gauge, which only the flat path ever moves.
    let flat_forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::MaxTaskVertices(5),
        edge: EdgeSplit::MaxFanout(1),
        pipeline: Pipeline::Off,
        layout: Layout::Flat,
        admit: Admit::Static(8),
    };
    // The admission forcing leg: run with a BFS clone that flags EVERY
    // query heavy, so `Admit::Adaptive` confines the whole batch to the
    // reserved capacity slice (2 of 8) and any batch of 3+ queries is
    // guaranteed to defer while slots sit free — the planner path
    // engages, and the answers still must not move.
    let admit_forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::Off,
        edge: EdgeSplit::Off,
        pipeline: Pipeline::Off,
        layout: Layout::Hashed,
        admit: Admit::Adaptive,
    };

    let mut split_engaged = false;
    let mut edge_engaged = false;
    let mut pipeline_engaged = false;
    let mut flat_engaged = false;
    let mut admit_engaged = false;
    for case in 0..cases {
        let case_seed = master_seed.wrapping_add(1 + case as u64 * 0x9e37);
        let mut rng = Rng::new(case_seed);
        let (mut g, desc) = random_graph(&mut rng, case_seed);
        let n = g.num_vertices();
        let nq = 1 + rng.below_usize(6);
        let queries = gen::random_pairs(n, nq, case_seed ^ 0x51ee7);
        let use_bibfs = rng.chance(0.4);
        if use_bibfs {
            g.ensure_in_edges();
        }

        let run = |cfg: Config| {
            if use_bibfs {
                run_batch(|| BiBfs::new(&g), n, &queries, cfg)
            } else {
                run_batch(|| Bfs::new(&g), n, &queries, cfg)
            }
        };
        let (base, _) = run(serial);
        for ci in 0..configs_per_case {
            let cfg = random_config(&mut rng);
            let (outs, engaged) = run(cfg);
            split_engaged |= engaged.subjobs;
            edge_engaged |= engaged.edge_ranges;
            flat_engaged |= engaged.flat;
            assert_eq!(
                outs, base,
                "fuzz case {case} (seed {case_seed:#x}, {desc}, \
                 bibfs={use_bibfs}) config {ci} {cfg:?} changed outputs \
                 vs the serial reference"
            );
        }
        let (outs, engaged) = run(forcing);
        split_engaged |= engaged.subjobs;
        edge_engaged |= engaged.edge_ranges;
        assert_eq!(
            outs, base,
            "fuzz case {case} (seed {case_seed:#x}, {desc}, \
             bibfs={use_bibfs}) edge-threshold-1 forcing config {forcing:?} \
             changed outputs vs the serial reference"
        );
        let (outs, engaged) = run(pipe_forcing);
        pipeline_engaged |= engaged.pipelined;
        assert_eq!(
            outs, base,
            "fuzz case {case} (seed {case_seed:#x}, {desc}, \
             bibfs={use_bibfs}) pipeline forcing config {pipe_forcing:?} \
             changed outputs vs the serial reference"
        );
        let (outs, engaged) = run(flat_forcing);
        flat_engaged |= engaged.flat;
        assert_eq!(
            outs, base,
            "fuzz case {case} (seed {case_seed:#x}, {desc}, \
             bibfs={use_bibfs}) flat-layout forcing config {flat_forcing:?} \
             changed outputs vs the serial reference"
        );
        // Both PPSP apps answer with the same Option<u32> distance, so
        // the all-heavy BFS clone compares against the same reference.
        let (outs, engaged) = run_batch(|| HeavyBfs { g: &g }, n, &queries, admit_forcing);
        admit_engaged |= engaged.deferred;
        assert_eq!(
            outs, base,
            "fuzz case {case} (seed {case_seed:#x}, {desc}, \
             bibfs={use_bibfs}) admission forcing config {admit_forcing:?} \
             changed outputs vs the serial reference"
        );
    }
    assert!(
        split_engaged,
        "no fuzz configuration ever executed a sub-job: the fuzzer is not \
         exercising the vertex-split path"
    );
    assert!(
        edge_engaged,
        "no fuzz configuration ever executed an edge-range job: the fuzzer \
         is not exercising the edge-split path"
    );
    assert!(
        pipeline_engaged,
        "no fuzz configuration ever ran a pipelined super-round: the fuzzer \
         is not exercising the ready-driven path"
    );
    assert!(
        flat_engaged,
        "no fuzz configuration ever engaged the flat layout: the fuzzer is \
         not exercising the arena/columnar path"
    );
    assert!(
        admit_engaged,
        "no fuzz configuration ever deferred a heavy query: the fuzzer is \
         not exercising the adaptive admission planner"
    );
}
