//! Randomized determinism fuzzer for the sub-lane + edge-level split
//! engine.
//!
//! The hand-written determinism suite sweeps a fixed grid; this fuzzer
//! drives the same guarantee through ~100 *random* corners: a seeded
//! `util::Rng` generates random graphs (five structural families,
//! including the pathological mega-hub and mono-hub) × random query
//! batches × random engine configurations `{threads, workers, capacity,
//! Sched, Split, EdgeSplit, Pipeline, Layout}`, and every
//! configuration's `QueryResult::out` vector must be bit-identical to
//! the serial reference run (`threads = 1`, static scheduler, all
//! splitting off, barrier rounds, the hashed-map layout). Each case
//! additionally runs one **edge-threshold-1 forcing configuration**
//! (`EdgeSplit::MaxFanout(1)` + a tiny vertex-split threshold), which
//! parks every multi-message outbox and dices it into single-edge
//! ranges — the most adversarial exercise of the park/range/fold replay
//! there is — one **pipeline forcing configuration** (`Pipeline::On`,
//! splitting off, 4 threads) whose ready-driven rounds are guaranteed to
//! engage, and one **flat-layout forcing configuration**
//! (`Layout::Flat` + stealing + both splits armed) whose arena stores
//! and columnar staging are guaranteed to engage (asserted at the end
//! via the `staging_bytes_peak` gauge, which only the flat path ever
//! moves). On a mismatch the failing case seed and configuration are
//! printed, so any regression reproduces with a one-line test.
//!
//! Since the serving layer, the random configuration also draws the
//! `Admit` knob (random static capacities and the adaptive planner —
//! inert for apps that flag nothing heavy, admission-width-throttling
//! otherwise), and each case runs one **admission forcing
//! configuration**: a BFS clone that flags EVERY query heavy under
//! `Admit::Adaptive`, so the whole batch squeezes through the reserved
//! capacity slice — deferrals are counted and asserted at the end, and
//! the outputs must still be bit-identical to the serial reference.
//!
//! `QUEGEL_BENCH_SMOKE=1` shrinks the case count for the CI smoke lane;
//! `QUEGEL_FUZZ_CASES=N` overrides it outright (the nightly deep-fuzz CI
//! lane runs 1000). The split thresholds are deliberately drawn small, so
//! both the vertex-range and the edge-range paths engage even on
//! fuzz-sized graphs — asserted at the end, to make sure the fuzz can
//! never silently degenerate into testing the unsplit paths.

use quegel::apps::ppsp::{oracle as ppsp_oracle, vbfs_query, Bfs, BiBfs, VersionedBfs, UNREACHED};
use quegel::coordinator::{Admit, EdgeSplit, Engine, Layout, Pipeline, Sched, Split};
use quegel::graph::{gen, Graph, MutationBatch, VertexId};
use quegel::network::Cluster;
use quegel::util::{env_flag, env_u64, env_usize, Rng};
use quegel::vertex::{Ctx, QueryApp};

/// One random engine configuration of a fuzz case.
#[derive(Debug, Clone, Copy)]
struct Config {
    threads: usize,
    workers: usize,
    capacity: usize,
    sched: Sched,
    split: Split,
    edge: EdgeSplit,
    pipeline: Pipeline,
    layout: Layout,
    admit: Admit,
}

fn random_config(rng: &mut Rng) -> Config {
    let sched = if rng.chance(0.3) {
        Sched::Static
    } else {
        Sched::Stealing
    };
    let split = match rng.below(4) {
        0 => Split::Off,
        1 => Split::Adaptive,
        // Small fixed thresholds, so fuzz-sized frontiers really split.
        2 => Split::MaxTaskVertices(1 + rng.below_usize(48)),
        _ => Split::MaxTaskVertices(64 + rng.below_usize(256)),
    };
    let edge = match rng.below(4) {
        0 => EdgeSplit::Off,
        1 => EdgeSplit::Adaptive,
        // Tiny fanout thresholds, so ordinary-degree vertices park too
        // (including ranges of a single edge).
        2 => EdgeSplit::MaxFanout(1 + rng.below_usize(8)),
        _ => EdgeSplit::MaxFanout(32 + rng.below_usize(256)),
    };
    // The pipelined path only engages when splitting stays disarmed, so a
    // random draw here mostly tests that Pipeline::On *degrades* to the
    // barrier path correctly; the dedicated forcing config below is what
    // guarantees the ready-driven rounds themselves run every case.
    let pipeline = if rng.chance(0.5) {
        Pipeline::On
    } else {
        Pipeline::Off
    };
    let layout = if rng.chance(0.5) {
        Layout::Flat
    } else {
        Layout::Hashed
    };
    // For apps that flag nothing heavy, Adaptive degenerates to
    // Static(capacity); small static payloads throttle the admission
    // width below the capacity — either way the answers must not move.
    let admit = if rng.chance(0.5) {
        Admit::Adaptive
    } else {
        Admit::Static(1 + rng.below_usize(8))
    };
    Config {
        threads: [2, 3, 4, 8][rng.below_usize(4)],
        workers: 1 + rng.below_usize(8),
        capacity: [1, 2, 8][rng.below_usize(3)],
        sched,
        split,
        edge,
        pipeline,
        layout,
        admit,
    }
}

/// Random graph from one of five structural families. Returns the graph
/// and a short description for failure messages.
fn random_graph(rng: &mut Rng, seed: u64) -> (Graph, String) {
    let n = 300 + rng.below_usize(900);
    match rng.below(5) {
        0 => {
            let deg = 3 + rng.below_usize(5);
            (
                gen::twitter_like(n, deg, seed),
                format!("twitter_like({n}, {deg}, {seed})"),
            )
        }
        1 => {
            let hub = 8 + rng.below_usize(24);
            let base = 2 + rng.below_usize(4);
            (
                gen::hub_concentrated(n, 8, hub, base, seed),
                format!("hub_concentrated({n}, 8, {hub}, {base}, {seed})"),
            )
        }
        2 => {
            let spoke = 3 + rng.below_usize(8);
            (
                gen::mega_hub(n, 8, spoke, seed),
                format!("mega_hub({n}, 8, {spoke}, {seed})"),
            )
        }
        3 => {
            let spoke = 1 + rng.below_usize(4);
            (
                gen::mono_hub(n, spoke, seed),
                format!("mono_hub({n}, {spoke}, {seed})"),
            )
        }
        _ => {
            let layers = 5 + rng.below_usize(15);
            let deg = 2 + rng.below_usize(4);
            (
                gen::webuk_like(n, layers, deg, seed),
                format!("webuk_like({n}, {layers}, {deg}, {seed})"),
            )
        }
    }
}

/// Which split machinery a run engaged, so the fuzzer can prove it never
/// degenerates into testing only the unsplit paths.
struct Engaged {
    subjobs: bool,
    edge_ranges: bool,
    pipelined: bool,
    flat: bool,
    deferred: bool,
}

/// BFS with every query flagged heavy — the admission forcing app. Same
/// compute as the library's [`Bfs`] (so outputs compare equal to the
/// serial reference of either PPSP app), but under `Admit::Adaptive` the
/// whole batch is confined to the reserved capacity slice and deferrals
/// are guaranteed whenever the batch outnumbers it.
struct HeavyBfs<'g> {
    g: &'g Graph,
}

impl<'g> QueryApp for HeavyBfs<'g> {
    type Query = (u32, u32);
    type VQ = u32;
    type Msg = ();
    type Agg = ();
    type Out = Option<u32>;

    fn is_heavy(&self, _q: &(u32, u32)) -> bool {
        true
    }

    fn init_activate(&self, q: &(u32, u32)) -> Vec<VertexId> {
        vec![q.0]
    }

    fn init_value(&self, q: &(u32, u32), v: VertexId) -> u32 {
        if v == q.0 {
            0
        } else {
            UNREACHED
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, d: &mut u32) {
        let step = ctx.superstep();
        let (_, t) = *ctx.query();
        if step == 1 {
            if v == t {
                ctx.force_terminate();
            }
            for &u in self.g.out(v) {
                ctx.send(u, ());
            }
            ctx.vote_halt();
            return;
        }
        if *d == UNREACHED {
            *d = (step - 1) as u32;
            if v == t {
                ctx.force_terminate();
            } else {
                for &u in self.g.out(v) {
                    ctx.send(u, ());
                }
            }
        }
        ctx.vote_halt();
    }

    fn combine(&self, _into: &mut (), _from: &()) -> bool {
        true
    }

    fn finish(
        &self,
        q: &(u32, u32),
        touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &(),
    ) -> Option<u32> {
        let t = q.1;
        for (v, &d) in touched {
            if v == t && d != UNREACHED {
                return Some(d);
            }
        }
        None
    }
}

/// Run one batch under one configuration, returning outputs in submission
/// order plus which split paths engaged.
fn run_batch<A, F>(mk: F, n: usize, queries: &[A::Query], cfg: Config) -> (Vec<A::Out>, Engaged)
where
    A: QueryApp,
    A::Out: Clone,
    F: FnOnce() -> A,
{
    let mut eng = Engine::new(mk(), Cluster::new(cfg.workers), n)
        .capacity(cfg.capacity)
        .threads(cfg.threads)
        .scheduler(cfg.sched)
        .split(cfg.split)
        .edge_split(cfg.edge)
        .pipeline(cfg.pipeline)
        .layout(cfg.layout)
        .admit(cfg.admit);
    let ids: Vec<_> = queries.iter().map(|q| eng.submit(q.clone())).collect();
    eng.run_until_idle();
    let outs = ids
        .iter()
        .map(|id| {
            eng.results()
                .iter()
                .find(|r| r.qid == *id)
                .expect("query completed")
                .out
                .clone()
        })
        .collect();
    let engaged = Engaged {
        subjobs: eng.metrics().subjobs_executed > 0,
        edge_ranges: eng.metrics().edge_ranges_split > 0,
        pipelined: eng.metrics().pipelined_rounds > 0,
        flat: eng.metrics().staging_bytes_peak > 0,
        deferred: eng.metrics().admit_deferrals > 0,
    };
    (outs, engaged)
}

#[test]
fn randomized_matrix_is_bit_identical_to_serial() {
    // QUEGEL_FUZZ_SEED picks a different deterministic case universe per
    // run (the nightly CI matrix fans out over seeds, so its legs cover
    // DISTINCT cases instead of repeating one batch); the default keeps
    // local and PR runs reproducible.
    let master_seed = env_u64("QUEGEL_FUZZ_SEED").unwrap_or(0x5eed_f022);
    let smoke = env_flag("QUEGEL_BENCH_SMOKE");
    let cases = env_usize("QUEGEL_FUZZ_CASES").unwrap_or(if smoke { 12 } else { 100 });
    let configs_per_case = 3;
    // The reference also pins the hashed-map layout, so every flat-layout
    // draw below is compared against the original stores.
    let serial = Config {
        threads: 1,
        workers: 4,
        capacity: 4,
        sched: Sched::Static,
        split: Split::Off,
        edge: EdgeSplit::Off,
        pipeline: Pipeline::Off,
        layout: Layout::Hashed,
        admit: Admit::Static(4),
    };
    // The edge-threshold-1 forcing leg: every outbox of 2+ messages is
    // parked and diced into single-edge ranges, and a tiny vertex
    // threshold keeps the vertex split in the mix, so the two replay
    // pipelines compose.
    let forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::MaxTaskVertices(5),
        edge: EdgeSplit::MaxFanout(1),
        pipeline: Pipeline::Off,
        layout: Layout::Hashed,
        admit: Admit::Static(8),
    };
    // The pipeline forcing leg: splitting stays off and threads > 1, so
    // every super-round takes the ready-driven per-(query, worker) path —
    // asserted below, per run, so the fuzz can never silently stop
    // exercising it.
    let pipe_forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::Off,
        edge: EdgeSplit::Off,
        pipeline: Pipeline::On,
        layout: Layout::Hashed,
        admit: Admit::Static(8),
    };
    // The flat-layout forcing leg: arena stores + columnar staging under
    // stealing with BOTH splits armed, so the flat replay pipelines (the
    // ordered sub-buffer and edge-range absorption into flat columns)
    // compose every case; engagement is proved per run via the
    // staging_bytes_peak gauge, which only the flat path ever moves.
    let flat_forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::MaxTaskVertices(5),
        edge: EdgeSplit::MaxFanout(1),
        pipeline: Pipeline::Off,
        layout: Layout::Flat,
        admit: Admit::Static(8),
    };
    // The admission forcing leg: run with a BFS clone that flags EVERY
    // query heavy, so `Admit::Adaptive` confines the whole batch to the
    // reserved capacity slice (2 of 8) and any batch of 3+ queries is
    // guaranteed to defer while slots sit free — the planner path
    // engages, and the answers still must not move.
    let admit_forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::Off,
        edge: EdgeSplit::Off,
        pipeline: Pipeline::Off,
        layout: Layout::Hashed,
        admit: Admit::Adaptive,
    };

    let mut split_engaged = false;
    let mut edge_engaged = false;
    let mut pipeline_engaged = false;
    let mut flat_engaged = false;
    let mut admit_engaged = false;
    for case in 0..cases {
        let case_seed = master_seed.wrapping_add(1 + case as u64 * 0x9e37);
        let mut rng = Rng::new(case_seed);
        let (mut g, desc) = random_graph(&mut rng, case_seed);
        let n = g.num_vertices();
        let nq = 1 + rng.below_usize(6);
        let queries = gen::random_pairs(n, nq, case_seed ^ 0x51ee7);
        let use_bibfs = rng.chance(0.4);
        if use_bibfs {
            g.ensure_in_edges();
        }

        let run = |cfg: Config| {
            if use_bibfs {
                run_batch(|| BiBfs::new(&g), n, &queries, cfg)
            } else {
                run_batch(|| Bfs::new(&g), n, &queries, cfg)
            }
        };
        let (base, _) = run(serial);
        for ci in 0..configs_per_case {
            let cfg = random_config(&mut rng);
            let (outs, engaged) = run(cfg);
            split_engaged |= engaged.subjobs;
            edge_engaged |= engaged.edge_ranges;
            flat_engaged |= engaged.flat;
            assert_eq!(
                outs, base,
                "fuzz case {case} (seed {case_seed:#x}, {desc}, \
                 bibfs={use_bibfs}) config {ci} {cfg:?} changed outputs \
                 vs the serial reference"
            );
        }
        let (outs, engaged) = run(forcing);
        split_engaged |= engaged.subjobs;
        edge_engaged |= engaged.edge_ranges;
        assert_eq!(
            outs, base,
            "fuzz case {case} (seed {case_seed:#x}, {desc}, \
             bibfs={use_bibfs}) edge-threshold-1 forcing config {forcing:?} \
             changed outputs vs the serial reference"
        );
        let (outs, engaged) = run(pipe_forcing);
        pipeline_engaged |= engaged.pipelined;
        assert_eq!(
            outs, base,
            "fuzz case {case} (seed {case_seed:#x}, {desc}, \
             bibfs={use_bibfs}) pipeline forcing config {pipe_forcing:?} \
             changed outputs vs the serial reference"
        );
        let (outs, engaged) = run(flat_forcing);
        flat_engaged |= engaged.flat;
        assert_eq!(
            outs, base,
            "fuzz case {case} (seed {case_seed:#x}, {desc}, \
             bibfs={use_bibfs}) flat-layout forcing config {flat_forcing:?} \
             changed outputs vs the serial reference"
        );
        // Both PPSP apps answer with the same Option<u32> distance, so
        // the all-heavy BFS clone compares against the same reference.
        let (outs, engaged) = run_batch(|| HeavyBfs { g: &g }, n, &queries, admit_forcing);
        admit_engaged |= engaged.deferred;
        assert_eq!(
            outs, base,
            "fuzz case {case} (seed {case_seed:#x}, {desc}, \
             bibfs={use_bibfs}) admission forcing config {admit_forcing:?} \
             changed outputs vs the serial reference"
        );
    }
    assert!(
        split_engaged,
        "no fuzz configuration ever executed a sub-job: the fuzzer is not \
         exercising the vertex-split path"
    );
    assert!(
        edge_engaged,
        "no fuzz configuration ever executed an edge-range job: the fuzzer \
         is not exercising the edge-split path"
    );
    assert!(
        pipeline_engaged,
        "no fuzz configuration ever ran a pipelined super-round: the fuzzer \
         is not exercising the ready-driven path"
    );
    assert!(
        flat_engaged,
        "no fuzz configuration ever engaged the flat layout: the fuzzer is \
         not exercising the arena/columnar path"
    );
    assert!(
        admit_engaged,
        "no fuzz configuration ever deferred a heavy query: the fuzzer is \
         not exercising the adaptive admission planner"
    );
}

/// One event of a random mutation schedule: the fuzzer interleaves
/// arrivals, mutation batches and explicit super-rounds on the simulated
/// clock, so queries pinned to old epochs routinely overlap batches that
/// create newer ones.
enum Ev {
    /// Submit the next query from the case's query list.
    Submit,
    /// Queue mutation batch `i` (applies at the next round boundary).
    Mutate(usize),
    /// Drive `k` explicit super-rounds before the next event.
    Rounds(usize),
}

/// Mutation-schedule fuzzer: random graphs × random mutation schedules
/// (edge deletes drawn from arcs that exist, edge adds between live
/// vertices, vertex adds wired both directions, vertex deletes) × random
/// `try_submit`/`try_mutate`/super-round interleavings × random engine
/// configurations. Every completed query is replayed against plain serial
/// BFS on the [`Graph::apply`]-folded snapshot of the epoch it pinned at
/// admission — the same serial oracle the hand-written suite uses — and
/// each random configuration must be `(epoch, out)`-bit-identical to its
/// own single-threaded twin (thread count can never re-time admission).
/// Two forcing legs per case compose the overlay with the split/flat and
/// pipelined machinery; engagement is asserted so the fuzz can never
/// silently degenerate into an immutable-graph test.
#[test]
fn random_mutation_schedules_replay_against_serial_snapshots() {
    // CI matrix knob: the mutations-off leg proves the rest of the suite
    // is independent of the versioning machinery.
    if std::env::var("QUEGEL_TEST_MUT").is_ok_and(|v| v == "off") {
        eprintln!("QUEGEL_TEST_MUT=off: skipping mutation-schedule fuzz");
        return;
    }

    let master_seed = env_u64("QUEGEL_FUZZ_SEED").unwrap_or(0x5eed_f022);
    let smoke = env_flag("QUEGEL_BENCH_SMOKE");
    let cases = env_usize("QUEGEL_FUZZ_CASES").unwrap_or(if smoke { 8 } else { 60 });
    let configs_per_case = 2;
    // Overlay × split/flat forcing: both splits armed with tiny thresholds
    // under the arena/columnar layout, reading through epoch overlays.
    let flat_forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::MaxTaskVertices(5),
        edge: EdgeSplit::MaxFanout(1),
        pipeline: Pipeline::Off,
        layout: Layout::Flat,
        admit: Admit::Static(8),
    };
    // Overlay × pipeline forcing: ready-driven rounds with mutations
    // landing between them.
    let pipe_forcing = Config {
        threads: 4,
        workers: 3,
        capacity: 8,
        sched: Sched::Stealing,
        split: Split::Off,
        edge: EdgeSplit::Off,
        pipeline: Pipeline::On,
        layout: Layout::Hashed,
        admit: Admit::Static(8),
    };

    let mut flat_engaged = false;
    let mut pipeline_engaged = false;
    let mut overlap_seen = false;
    for case in 0..cases {
        // A different salt than the immutable fuzzer, so the two tests
        // cover distinct graph/config universes under one master seed.
        let case_seed = master_seed.wrapping_add(0xbeef + case as u64 * 0x9e37);
        let mut rng = Rng::new(case_seed);
        let (g, desc) = random_graph(&mut rng, case_seed);
        let n = g.num_vertices();
        let heavy_every = if rng.chance(0.5) { 2 + rng.below(4) as u32 } else { 0 };

        // Build the batch chain against serial folds, so every op is valid
        // by construction (deletes name arcs that exist, adds touch live
        // vertices) and the folds double as the oracle's snapshots.
        let n_batches = 1 + rng.below_usize(3);
        let mut live: Vec<bool> = vec![true; n];
        let mut folds: Vec<Graph> = vec![g.clone()];
        let mut batches: Vec<MutationBatch> = Vec::new();
        for _ in 0..n_batches {
            let cur = folds.last().unwrap();
            let live_ids: Vec<u32> = (0..cur.num_vertices() as u32)
                .filter(|&v| live[v as usize])
                .collect();
            let mut b = MutationBatch::new();
            for _ in 0..(1 + rng.below_usize(3)) {
                let v = live_ids[rng.below_usize(live_ids.len())];
                let out = cur.out(v);
                if !out.is_empty() {
                    b.delete_edge(v, out[rng.below_usize(out.len())]);
                }
            }
            for _ in 0..(1 + rng.below_usize(3)) {
                let u = live_ids[rng.below_usize(live_ids.len())];
                let w = live_ids[rng.below_usize(live_ids.len())];
                b.add_edge(u, w);
            }
            if rng.chance(0.4) {
                let nv = cur.num_vertices() as u32;
                let x = live_ids[rng.below_usize(live_ids.len())];
                let y = live_ids[rng.below_usize(live_ids.len())];
                b.add_vertex().add_edge(nv, x).add_edge(y, nv);
                live.push(true);
            }
            if rng.chance(0.3) {
                let v = live_ids[rng.below_usize(live_ids.len())];
                b.delete_vertex(v);
                live[v as usize] = false;
            }
            folds.push(cur.apply(&b));
            batches.push(b);
        }

        // The interleaving: a burst of arrivals, maybe some rounds, then
        // the next batch; stragglers arrive after the last batch.
        let mut schedule: Vec<Ev> = Vec::new();
        let mut n_submits = 0usize;
        for bi in 0..batches.len() {
            for _ in 0..(1 + rng.below_usize(3)) {
                schedule.push(Ev::Submit);
                n_submits += 1;
            }
            if rng.chance(0.7) {
                schedule.push(Ev::Rounds(1 + rng.below_usize(2)));
            }
            schedule.push(Ev::Mutate(bi));
        }
        for _ in 0..(1 + rng.below_usize(3)) {
            schedule.push(Ev::Submit);
            n_submits += 1;
        }
        // Queries stay within the epoch-0 id range, which every later
        // version also contains (deleted vertices keep their slots).
        let queries = gen::random_pairs(n, n_submits, case_seed ^ 0x77aa);

        let run = |cfg: Config| {
            let mut app = VersionedBfs::new(g.clone());
            app.heavy_every = heavy_every;
            let mut eng = Engine::new(app, Cluster::new(cfg.workers), n)
                .capacity(cfg.capacity)
                .threads(cfg.threads)
                .scheduler(cfg.sched)
                .split(cfg.split)
                .edge_split(cfg.edge)
                .pipeline(cfg.pipeline)
                .layout(cfg.layout)
                .admit(cfg.admit);
            let mut ids = Vec::new();
            let mut qi = 0usize;
            for ev in &schedule {
                match ev {
                    Ev::Submit => {
                        let (s, t) = queries[qi];
                        qi += 1;
                        ids.push(
                            eng.try_submit(vbfs_query(s, t), eng.sim_time())
                                .expect("queue accepts"),
                        );
                    }
                    Ev::Mutate(bi) => {
                        eng.try_mutate(batches[*bi].clone(), eng.sim_time())
                            .expect("app supports mutations");
                    }
                    Ev::Rounds(k) => {
                        for _ in 0..*k {
                            eng.super_round();
                        }
                    }
                }
            }
            eng.run_until_idle();
            // Engagement: every batch landed and the overlay really held
            // delta bytes at some point — the fuzz must never degenerate
            // into an immutable-graph run.
            assert_eq!(
                eng.metrics().epochs_applied,
                batches.len() as u64,
                "fuzz case {case} (seed {case_seed:#x}, {desc}) {cfg:?}: \
                 not every mutation batch was applied"
            );
            assert!(
                eng.metrics().delta_bytes_peak > 0,
                "fuzz case {case} (seed {case_seed:#x}, {desc}) {cfg:?}: \
                 the delta overlay never engaged"
            );
            let recs: Vec<(u64, Option<u32>)> = ids
                .iter()
                .map(|id| {
                    let r = eng
                        .results()
                        .iter()
                        .find(|r| r.qid == *id)
                        .expect("query completed");
                    (r.stats.epoch, r.out)
                })
                .collect();
            let flat = eng.metrics().staging_bytes_peak > 0;
            let piped = eng.metrics().pipelined_rounds > 0;
            (recs, flat, piped)
        };
        let check = |recs: &[(u64, Option<u32>)], what: &str| {
            for (i, &(e, out)) in recs.iter().enumerate() {
                let (s, t) = queries[i];
                let want = ppsp_oracle::bfs_dist(&folds[e as usize], s, t);
                assert_eq!(
                    out,
                    (want != UNREACHED).then_some(want),
                    "fuzz case {case} (seed {case_seed:#x}, {desc}) {what}: \
                     query ({s},{t}) pinned to epoch {e} diverged from the \
                     serial snapshot replay"
                );
            }
        };

        for ci in 0..configs_per_case {
            let cfg = random_config(&mut rng);
            let (serial_recs, _, _) = run(Config { threads: 1, ..cfg });
            check(&serial_recs, "single-threaded twin");
            overlap_seen |= serial_recs
                .iter()
                .any(|&(e, _)| e < batches.len() as u64 && serial_recs.iter().any(|&(e2, _)| e2 > e));
            let (recs, _, _) = run(cfg);
            assert_eq!(
                recs, serial_recs,
                "fuzz case {case} (seed {case_seed:#x}, {desc}) config {ci} \
                 {cfg:?} changed the (epoch, out) stream vs its \
                 single-threaded twin"
            );
        }
        let (recs, flat, _) = run(flat_forcing);
        check(&recs, "flat/split forcing config");
        flat_engaged |= flat;
        let (recs, _, piped) = run(pipe_forcing);
        check(&recs, "pipeline forcing config");
        pipeline_engaged |= piped;

        // Process-axis forcing leg (every ~10th case — each run spawns
        // two worker processes, so the leg is sampled rather than
        // blanket): the same random schedule through a 2-process engine
        // must replay its in-process twin's (epoch, out) stream bit for
        // bit, with the exchange demonstrably on the wire.
        if case % 10 == 0 {
            use quegel::coordinator::remote::{libtest_worker_args, ProcEngine};
            use quegel::coordinator::EngineConfig;
            let pcfg = EngineConfig {
                capacity: 8,
                threads: 1,
                pipeline: Pipeline::Off,
                layout: Layout::Flat,
                admit: Admit::Static(8),
                ..EngineConfig::default()
            };
            let run_procs = |procs: usize| {
                let mut app = VersionedBfs::new(g.clone());
                app.heavy_every = heavy_every;
                let mut pe = ProcEngine::new(
                    app,
                    Cluster::new(3),
                    n,
                    pcfg,
                    procs,
                    &libtest_worker_args("multiproc_worker_entry"),
                );
                let mut ids = Vec::new();
                let mut qi = 0usize;
                for ev in &schedule {
                    match ev {
                        Ev::Submit => {
                            let (s, t) = queries[qi];
                            qi += 1;
                            ids.push(
                                pe.try_submit(vbfs_query(s, t), pe.sim_time())
                                    .expect("queue accepts"),
                            );
                        }
                        Ev::Mutate(bi) => {
                            pe.try_mutate(batches[*bi].clone(), pe.sim_time())
                                .expect("app supports mutations");
                        }
                        Ev::Rounds(k) => {
                            for _ in 0..*k {
                                pe.super_round();
                            }
                        }
                    }
                }
                pe.run_until_idle();
                let results = pe.take_results();
                let recs: Vec<(u64, Option<u32>)> = ids
                    .iter()
                    .map(|id| {
                        let r = results
                            .iter()
                            .find(|r| r.qid == *id)
                            .expect("query completed");
                        (r.stats.epoch, r.out)
                    })
                    .collect();
                let wire = pe.metrics().bytes_on_wire;
                pe.shutdown();
                (recs, wire)
            };
            let (twin, twin_wire) = run_procs(1);
            assert_eq!(
                twin_wire, 0,
                "fuzz case {case}: a 1-process engine must not touch the wire"
            );
            check(&twin, "in-process twin of the process-axis leg");
            let (recs, wire) = run_procs(2);
            assert_eq!(
                recs, twin,
                "fuzz case {case} (seed {case_seed:#x}, {desc}): the \
                 2-process run changed the (epoch, out) stream vs its \
                 in-process twin"
            );
            assert!(
                wire > 0,
                "fuzz case {case}: the 2-process run never put bytes on \
                 the wire"
            );
        }
    }
    assert!(
        flat_engaged,
        "no mutation-fuzz configuration ever engaged the flat layout: the \
         overlay × arena/columnar composition is not being exercised"
    );
    assert!(
        pipeline_engaged,
        "no mutation-fuzz configuration ever ran a pipelined super-round: \
         the overlay × ready-driven composition is not being exercised"
    );
    assert!(
        overlap_seen,
        "no fuzz case ever completed queries pinned to distinct epochs: \
         the schedules are not creating version overlap"
    );
}

/// Worker-process entrypoint for this test binary: the process-axis fuzz
/// leg spawns `current_exe()` filtered (`--exact`) to exactly this test,
/// whose body serves the remote worker protocol. Without the worker env
/// knobs it passes as an immediate no-op.
#[test]
fn multiproc_worker_entry() {
    quegel::coordinator::remote::maybe_serve_worker::<VersionedBfs>();
}
