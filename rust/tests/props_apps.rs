//! Property tests over application-level invariants: XML semantics
//! relationships, terrain geometry, analytics vs oracles, and the RDF
//! search's monotonicity in δ_max.

use quegel::apps::gkws;
use quegel::apps::terrain::baseline::{dijkstra, hausdorff};
use quegel::apps::terrain::{Dem, TerrainNet, TerrainSssp};
use quegel::apps::xml;
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::network::Cluster;
use quegel::prop;
use quegel::util::Rng;
use quegel::{prop_assert, prop_assert_eq};

fn corpus(rng: &mut Rng) -> xml::XmlTree {
    xml::data::generate(&xml::XmlGenConfig {
        dblp_like: rng.chance(0.5),
        records: 30 + rng.below_usize(80),
        vocab: 50 + rng.below_usize(80),
        seed: rng.next_u64(),
    })
}

/// Every SLCA is an ELCA (SLCA ⊆ ELCA, by definition), and every SLCA root
/// appears in the MaxMatch vertex set.
#[test]
fn prop_xml_semantics_containment() {
    prop::check("xml-containment", 10, |rng| {
        let t = corpus(rng);
        for q in xml::data::query_pool(&t, 4, 2, rng.next_u64()) {
            let slca = xml::oracle::slca(&t, &q);
            let elca = xml::oracle::elca(&t, &q);
            let mm = xml::oracle::maxmatch(&t, &q);
            for v in &slca {
                prop_assert!(elca.contains(v), "SLCA {v} not in ELCA q={q:?}");
                prop_assert!(mm.contains(v), "SLCA {v} not in MaxMatch q={q:?}");
            }
            // MaxMatch vertices all descend from some SLCA.
            for &v in &mm {
                let mut cur = v;
                let mut ok = slca.contains(&cur);
                while !ok && t.parent[cur as usize] != xml::data::NO_PARENT {
                    cur = t.parent[cur as usize];
                    ok = slca.contains(&cur);
                }
                prop_assert!(ok, "MaxMatch vertex {v} not under any SLCA");
            }
        }
        Ok(())
    });
}

/// Distributed ELCA equals the oracle on random corpora (SLCA variants are
/// covered in props.rs).
#[test]
fn prop_xml_elca_matches_oracle() {
    prop::check("xml-elca", 8, |rng| {
        let t = corpus(rng);
        for q in xml::data::query_pool(&t, 4, 2, rng.next_u64()) {
            let want = xml::oracle::elca(&t, &q);
            let mut eng = Engine::new(xml::Elca::new(&t), Cluster::new(4), t.len());
            let got: Vec<u32> = eng.run_one(q.clone()).out.iter().map(|r| r.0).collect();
            prop_assert_eq!(&got, &want, "q={:?}", q);
        }
        Ok(())
    });
}

/// Terrain: the distributed SSSP distance equals Dijkstra, lower-bounds
/// never break (d >= euclid), and the polyline length equals the distance.
#[test]
fn prop_terrain_sssp_invariants() {
    prop::check("terrain-sssp", 6, |rng| {
        let w = 6 + rng.below_usize(8);
        let h = 6 + rng.below_usize(8);
        let dem = Dem::fractal(w, h, 10.0, 50.0 + rng.f64() * 150.0, rng.next_u64());
        let net = TerrainNet::build(&dem, 3.0 + rng.f64() * 4.0);
        let n = net.graph.num_vertices();
        let s = net.corner(rng.below_usize(w), rng.below_usize(h));
        let t = net.corner(rng.below_usize(w), rng.below_usize(h));
        if s == t {
            return Ok(());
        }
        let mut eng = Engine::new(TerrainSssp::new(&net), Cluster::new(4), n);
        let out = eng.run_one((s, t)).out;
        prop_assert!(out.reached, "terrain networks are connected");
        let want = dijkstra(&net.graph, s, Some(t)).0[t as usize];
        prop_assert!(
            (out.dist - want).abs() < 1e-6,
            "dist {} vs dijkstra {}",
            out.dist,
            want
        );
        prop_assert!(
            out.dist >= net.euclid(s, t) - 1e-6,
            "below the euclidean lower bound"
        );
        let len: f64 = out
            .path
            .windows(2)
            .map(|p| {
                ((p[0].0 - p[1].0).powi(2) + (p[0].1 - p[1].1).powi(2) + (p[0].2 - p[1].2).powi(2))
                    .sqrt()
            })
            .sum();
        // Edge weights are f32 while coordinates are f64, so the polyline
        // length accumulates f32 rounding relative to the reported distance.
        prop_assert!(
            (len - out.dist).abs() < 1e-4 * out.dist.max(1.0),
            "polyline length mismatch: {} vs {}",
            len,
            out.dist
        );
        // Hausdorff distance of a path to itself is 0.
        prop_assert!(hausdorff(&out.path, &out.path) < 1e-9, "hdist self");
        Ok(())
    });
}

/// RDF keyword search: results grow monotonically with δ_max, and every
/// reported hop respects the bound.
#[test]
fn prop_gkws_delta_monotone() {
    prop::check("gkws-monotone", 6, |rng| {
        let g = gkws::data::generate(&gkws::RdfGenConfig {
            resources: 200 + rng.below_usize(400),
            avg_deg: 2 + rng.below_usize(4),
            predicates: 10 + rng.below_usize(20),
            vocab: 40 + rng.below_usize(60),
            seed: rng.next_u64(),
        });
        let kw = gkws::data::query_pool(&g, 1, 2, rng.next_u64()).pop().unwrap();
        let mut prev = 0usize;
        for dmax in 1..=4u32 {
            let mut eng = Engine::new(gkws::KeywordSearch::new(&g), Cluster::new(4), g.len());
            let roots = eng
                .run_one(gkws::query::GkwsQuery {
                    keywords: kw.clone(),
                    delta_max: dmax,
                })
                .out;
            prop_assert!(
                roots.len() >= prev,
                "root count must grow with delta_max ({} < {prev} at {dmax})",
                roots.len()
            );
            for (_, fields) in &roots {
                for f in fields {
                    prop_assert!(f.1 <= dmax, "hop {} exceeds delta_max {dmax}", f.1);
                }
            }
            prev = roots.len();
        }
        Ok(())
    });
}

/// Analytics: PageRank mass conservation and CC label idempotence on
/// random graphs.
#[test]
fn prop_analytics_invariants() {
    prop::check("analytics", 6, |rng| {
        let n = 100 + rng.below_usize(300);
        let g = gen::btc_like(n, 10 + rng.below_usize(30), 3, rng.next_u64());
        // PageRank sums to 1.
        let mut eng = Engine::new(
            quegel::analytics::PageRank::new(&g),
            Cluster::new(4),
            g.num_vertices(),
        )
        .max_supersteps(200);
        let pr = eng
            .run_one(quegel::analytics::pagerank::PrConfig::default())
            .out;
        let total: f64 = pr.iter().map(|&(_, r)| r).sum();
        prop_assert!((total - 1.0).abs() < 1e-6, "rank mass {total}");
        // CC labels are the component minima (idempotent under re-run).
        let want = quegel::analytics::components::components_oracle(&g);
        let mut eng = Engine::new(
            quegel::analytics::ConnectedComponents::new(&g),
            Cluster::new(4),
            g.num_vertices(),
        )
        .max_supersteps(10_000);
        let got = eng.run_one(()).out;
        for (v, l) in got {
            prop_assert_eq!(l, want[v as usize], "cc label of {}", v);
        }
        Ok(())
    });
}
