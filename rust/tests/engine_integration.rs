//! Integration tests for the superstep-sharing engine: scheduling,
//! capacity, latency accounting and the Figure-1 load-balancing effect.

use quegel::apps::ppsp::{oracle, Bfs, BiBfs, UNREACHED};
use quegel::coordinator::{EdgeSplit, Engine, Pipeline, Split};
use quegel::graph::gen;
use quegel::network::{Cluster, CostModel};

#[test]
fn batch_results_match_serial_results() {
    let g = gen::twitter_like(800, 5, 201);
    let queries = gen::random_pairs(800, 24, 202);

    // Serial: one query at a time.
    let mut serial = Vec::new();
    for &q in &queries {
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 800).capacity(1);
        serial.push(eng.run_one(q).out);
    }
    // Shared: all queries in flight together.
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 800).capacity(8);
    let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
    eng.run_until_idle();
    for (i, id) in ids.iter().enumerate() {
        let r = eng.results().iter().find(|r| r.qid == *id).unwrap();
        assert_eq!(r.out, serial[i], "query {i}");
        let want = oracle::bfs_dist(&g, queries[i].0, queries[i].1);
        assert_eq!(r.out, (want != UNREACHED).then_some(want));
    }
}

#[test]
fn capacity_is_never_exceeded() {
    let g = gen::twitter_like(500, 4, 203);
    for c in [1usize, 2, 5] {
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 500).capacity(c);
        for q in gen::random_pairs(500, 20, 204) {
            eng.submit(q);
        }
        eng.run_until_idle();
        assert!(
            eng.metrics().peak_inflight <= c,
            "peak {} > C = {c}",
            eng.metrics().peak_inflight
        );
        assert_eq!(eng.results().len(), 20);
    }
}

#[test]
fn superstep_sharing_beats_one_at_a_time() {
    // The paper's core claim (Table 7a): C = 8 is ~3x faster than C = 1 on
    // batch workloads, because barriers are shared and bandwidth is filled.
    let mut g = gen::twitter_like(3_000, 8, 205);
    g.ensure_in_edges();
    let queries = gen::random_pairs(3_000, 32, 206);

    let run = |c: usize| -> f64 {
        let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(8), 3_000).capacity(c);
        for &q in &queries {
            eng.submit(q);
        }
        eng.run_until_idle();
        eng.sim_time()
    };
    let t1 = run(1);
    let t8 = run(8);
    assert!(
        t8 < t1 * 0.6,
        "sharing must cut simulated time: C=1 {t1:.3}s vs C=8 {t8:.3}s"
    );
}

#[test]
fn figure1_load_balancing_effect() {
    // Two queries with opposite per-worker skew: shared super-rounds cost
    // max(sum) per worker instead of sum(max) — strictly less total time.
    let cost = CostModel {
        per_vertex_compute_s: 1e-3, // exaggerate compute skew
        barrier_latency_s: 10e-3,
        ..Default::default()
    };
    let g = gen::twitter_like(2_000, 6, 207);
    let queries = gen::random_pairs(2_000, 8, 208);

    let run = |c: usize| -> f64 {
        let mut eng =
            Engine::new(Bfs::new(&g), Cluster::with_cost(2, cost.clone()), 2_000).capacity(c);
        for &q in &queries {
            eng.submit(q);
        }
        eng.run_until_idle();
        eng.sim_time()
    };
    let individual = run(1);
    let shared = run(8);
    assert!(
        shared < individual,
        "shared {shared:.4}s !< individual {individual:.4}s"
    );
}

#[test]
fn latency_includes_queue_wait() {
    let g = gen::twitter_like(500, 4, 209);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(2), 500).capacity(1);
    for q in gen::random_pairs(500, 6, 210) {
        eng.submit(q);
    }
    eng.run_until_idle();
    let mut results: Vec<_> = eng.results().to_vec();
    results.sort_by_key(|r| r.qid);
    // With C = 1, later queries must have waited in the queue.
    let first = &results[0].stats;
    let last = &results[5].stats;
    assert!(last.started_at > first.started_at);
    assert!(last.latency() >= last.processing());
}

#[test]
fn truncation_guard_fires() {
    // An app that never halts gets cut at max_supersteps.
    struct Endless;
    impl quegel::vertex::QueryApp for Endless {
        type Query = ();
        type VQ = ();
        type Msg = ();
        type Agg = ();
        type Out = ();
        fn init_activate(&self, _q: &()) -> Vec<u32> {
            vec![0]
        }
        fn init_value(&self, _q: &(), _v: u32) {}
        fn compute(&self, ctx: &mut quegel::vertex::Ctx<'_, Self>, _v: u32, _vq: &mut ()) {
            ctx.send(0, ()); // self-message forever
            ctx.vote_halt();
        }
        fn finish(
            &self,
            _q: &(),
            _touched: &mut dyn Iterator<Item = (u32, &())>,
            _agg: &(),
        ) {
        }
    }
    let mut eng = Engine::new(Endless, Cluster::new(1), 1).max_supersteps(50);
    let r = eng.run_one(());
    assert!(r.stats.truncated);
    assert_eq!(r.stats.supersteps, 50);
}

#[test]
fn metrics_accumulate_across_queries() {
    let g = gen::twitter_like(400, 4, 211);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 400);
    for q in gen::random_pairs(400, 5, 212) {
        eng.submit(q);
    }
    eng.run_until_idle();
    let m = eng.metrics();
    assert!(m.super_rounds > 0);
    assert!(m.total_messages > 0);
    assert!(m.total_bytes > m.total_messages); // headers included
    assert!(m.sim_time > 0.0);
    assert!(m.wall_time > 0.0);
}

#[test]
fn run_one_drains_its_result_and_metrics_count_completions() {
    // Regression: interactive sessions that only ever call `run_one` must
    // not accumulate results, and completed-query accounting must live in
    // `EngineMetrics` whether or not `take_results` is ever called.
    let g = gen::twitter_like(500, 4, 216);
    let queries = gen::random_pairs(500, 30, 217);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 500).capacity(4);
    for (i, &q) in queries.iter().enumerate() {
        let r = eng.run_one(q);
        let want = oracle::bfs_dist(&g, q.0, q.1);
        assert_eq!(r.out, (want != UNREACHED).then_some(want));
        assert!(
            eng.results().is_empty(),
            "run_one leaked a result into the buffer at query {i}"
        );
        assert_eq!(eng.metrics().queries_completed, i as u64 + 1);
    }
    // Mixed usage: a batch-submitted query completed by run_one's
    // run_until_idle stays claimable via results()/take_results, and every
    // completion is counted exactly once.
    let extra = eng.submit(queries[0]);
    let _ = eng.run_one(queries[1]);
    assert_eq!(eng.results().len(), 1);
    assert_eq!(eng.results()[0].qid, extra);
    assert_eq!(
        eng.metrics().queries_completed,
        queries.len() as u64 + 2,
        "completion accounting must not depend on take_results"
    );
    assert_eq!(eng.take_results().len(), 1);
    assert!(eng.results().is_empty());
}

#[test]
fn reset_metrics_isolates_sessions() {
    // Regression: scheduler counters (jobs_executed / steals, and the
    // sub-lane split's subjobs_executed) are per-`WorkerPool::run` batch
    // and only ever accumulate in `EngineMetrics`, so a long-lived engine
    // serving one `run_one` session after another reports the SUM of all
    // sessions unless the caller can reset between them.
    let g = gen::twitter_like(800, 5, 218);
    let queries = gen::random_pairs(800, 4, 219);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 800)
        .capacity(4)
        .threads(4);

    let _ = eng.run_one(queries[0]);
    let first_jobs = eng.metrics().jobs_executed();
    assert!(first_jobs > 0, "a threaded run must dispatch pool jobs");

    // Without a reset, the second session reads the first one's totals.
    let _ = eng.run_one(queries[1]);
    assert!(eng.metrics().jobs_executed() > first_jobs);

    // With a reset, counters reflect exactly one session again.
    eng.reset_metrics();
    assert_eq!(eng.metrics().jobs_executed(), 0);
    assert_eq!(eng.metrics().steals(), 0);
    assert_eq!(eng.metrics().super_rounds, 0);
    assert_eq!(eng.metrics().queries_completed, 0);
    let r = eng.run_one(queries[2]);
    let want = oracle::bfs_dist(&g, queries[2].0, queries[2].1);
    assert_eq!(r.out, (want != UNREACHED).then_some(want));
    assert_eq!(
        eng.metrics().queries_completed, 1,
        "post-reset counters must be session-sized, not lifetime-sized"
    );
    assert!(eng.metrics().jobs_executed() > 0);
    assert!(eng.metrics().super_rounds > 0);
    // The simulated clock is engine state, not a counter: it must survive
    // the reset and keep sim_time in sync.
    assert!(eng.sim_time() > 0.0);
    assert!((eng.metrics().sim_time - eng.sim_time()).abs() < 1e-12);
}

#[test]
fn bare_metrics_reset_preserves_engine_lifetime_fields() {
    // Regression: `EngineMetrics::reset()` used to wipe the whole struct,
    // so a serving loop calling `metrics_mut().reset()` directly between
    // sessions (bypassing `Engine::reset_metrics` and its clock re-sync)
    // left `sim_time` stale at zero until the next super-round and
    // permanently lost the `peak_inflight` / `max_edge_task` high-water
    // marks.
    let g = gen::twitter_like(800, 5, 220);
    let queries = gen::random_pairs(800, 3, 221);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 800)
        .capacity(4)
        .threads(2);

    let _ = eng.run_one(queries[0]);
    let sim = eng.metrics().sim_time;
    let peak = eng.metrics().peak_inflight;
    let fan = eng.metrics().max_edge_task;
    assert!(sim > 0.0);
    assert_eq!(peak, 1);
    assert!(fan > 0, "BFS on twitter_like must fan out");

    eng.metrics_mut().reset();
    let m = eng.metrics();
    assert_eq!(m.queries_completed, 0);
    assert_eq!(m.super_rounds, 0);
    assert_eq!(m.jobs_executed(), 0);
    assert!(
        (m.sim_time - sim).abs() < 1e-12,
        "bare reset must keep the clock mirror: {} vs {sim}",
        m.sim_time
    );
    assert_eq!(m.peak_inflight, peak, "high-water mark survives reset");
    assert_eq!(m.max_edge_task, fan, "high-water mark survives reset");

    let r = eng.run_one(queries[1]);
    let want = oracle::bfs_dist(&g, queries[1].0, queries[1].1);
    assert_eq!(r.out, (want != UNREACHED).then_some(want));
    let m = eng.metrics();
    assert_eq!(m.queries_completed, 1, "counters are session-sized");
    assert!(
        m.sim_time > sim,
        "the clock keeps advancing from the preserved value, not from zero"
    );
    assert!(m.max_edge_task >= fan, "high-water marks only ever rise");
}

#[test]
fn phase_busy_accounting_matches_execution_mode() {
    // Phase metrics invariants. Barrier rounds on a serial engine time the
    // three phases as *disjoint wall segments*, so their sum is bounded by
    // wall_time (undershooting by coordinator-only work: admission, result
    // pushes) and nothing ever overlaps. Pipelined rounds time per-phase
    // *busy* seconds from inside pool jobs, so the sum is bounded by
    // threads x wall_time instead, and `overlap_time` — wall time with
    // two-plus phases simultaneously live — is a sub-interval of the wall.
    let g = gen::twitter_like(2_000, 6, 222);
    let queries = gen::random_pairs(2_000, 16, 223);
    let eps = 1e-4;

    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 2_000)
        .capacity(8)
        .threads(1)
        .pipeline(Pipeline::Off);
    for &q in &queries {
        eng.submit(q);
    }
    eng.run_until_idle();
    let m = eng.metrics();
    assert!(m.wall_time > 0.0);
    let sum = m.compute_time + m.exchange_time + m.barrier_time;
    assert!(sum > 0.0);
    assert!(
        sum <= m.wall_time * 1.05 + eps,
        "serial barrier phases are disjoint wall segments: sum {sum} vs wall {}",
        m.wall_time
    );
    assert_eq!(m.overlap_time, 0.0, "barrier rounds never overlap phases");
    assert_eq!(m.pipelined_rounds, 0);

    let threads = 4;
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 2_000)
        .capacity(8)
        .threads(threads)
        .split(Split::Off)
        .edge_split(EdgeSplit::Off)
        .pipeline(Pipeline::On);
    for &q in &queries {
        eng.submit(q);
    }
    eng.run_until_idle();
    let m = eng.metrics();
    assert!(
        m.pipelined_rounds > 0,
        "splitting off + threads > 1 must engage the ready-driven path"
    );
    assert!(m.wall_time > 0.0);
    let busy = m.compute_time + m.exchange_time + m.barrier_time;
    assert!(busy > 0.0);
    assert!(
        busy <= threads as f64 * m.wall_time * 1.05 + eps,
        "phase busy sum {busy} must fit in threads x wall = {threads} x {}",
        m.wall_time
    );
    assert!(
        m.overlap_time <= m.wall_time + eps,
        "overlap {} is a wall-time sub-interval (wall {})",
        m.overlap_time,
        m.wall_time
    );
}

#[test]
fn try_submit_backpressure_and_arrival_accounting() {
    // Serving front-end regression: `QueryStats::submitted_at` used to
    // double as the arrival stamp, so a request that waited OUTSIDE a
    // bounded submission queue (back-pressured, re-offered later) lost
    // that wait from its latency. Arrival is recorded separately now:
    // `latency()` covers arrival -> finish and `queueing()` covers
    // arrival -> start, whichever side of the queue the waiting happened.
    let g = gen::twitter_like(500, 4, 224);
    let queries = gen::random_pairs(500, 4, 225);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(2), 500)
        .capacity(1)
        .queue_bound(1);

    let _a = eng.try_submit(queries[0], 0.0).expect("queue empty");
    assert_eq!(eng.queue_depth(), 1);
    // The bound is hit: the request comes back to the caller, untouched.
    let rejected = eng.try_submit(queries[1], 0.0).unwrap_err();
    assert_eq!(rejected, queries[1]);
    assert_eq!(eng.queue_depth(), 1);

    // One super-round admits the queued query and frees the bound; the
    // simulated clock has advanced past the rejected request's arrival.
    assert!(eng.super_round());
    let waited_until = eng.sim_time();
    assert!(waited_until > 0.0);
    let qid_b = eng
        .try_submit(queries[1], 0.0)
        .expect("bound freed after admission");
    eng.run_until_idle();

    let rb = eng.results().iter().find(|r| r.qid == qid_b).unwrap();
    assert_eq!(rb.stats.arrived_at, 0.0, "arrival is the caller's stamp");
    assert!(
        rb.stats.submitted_at >= waited_until,
        "queue entry {} must postdate the back-pressure wait {}",
        rb.stats.submitted_at,
        waited_until
    );
    assert!(
        rb.stats.queueing() >= waited_until,
        "queueing delay must cover the wait BEFORE queue entry"
    );
    assert!(
        (rb.stats.latency() - (rb.stats.queueing() + rb.stats.processing())).abs() < 1e-12,
        "latency decomposes into queueing + processing"
    );

    // The engine's streaming sketches saw every completion, and the top
    // quantile is exactly the worst observed latency (no bucket error at
    // the clamped endpoints).
    let m = eng.metrics();
    assert_eq!(m.latency.count(), 2);
    assert_eq!(m.queueing.count(), 2);
    let worst = eng
        .results()
        .iter()
        .map(|r| r.stats.latency())
        .fold(0.0f64, f64::max);
    assert!((eng.metrics().latency.quantile(1.0) - worst).abs() < 1e-12);
}

#[test]
fn interleaved_submission_works() {
    // Queries submitted while others are in flight join later super-rounds.
    let g = gen::twitter_like(600, 4, 213);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 600).capacity(4);
    let q1 = gen::random_pairs(600, 4, 214);
    let q2 = gen::random_pairs(600, 4, 215);
    for &q in &q1 {
        eng.submit(q);
    }
    // Run a couple of super-rounds, then add more queries mid-flight.
    eng.super_round();
    eng.super_round();
    for &q in &q2 {
        eng.submit(q);
    }
    eng.run_until_idle();
    assert_eq!(eng.results().len(), 8);
    for r in eng.results() {
        let (s, t) = if (r.qid as usize) < 4 {
            q1[r.qid as usize]
        } else {
            q2[r.qid as usize - 4]
        };
        let want = oracle::bfs_dist(&g, s, t);
        assert_eq!(r.out, (want != UNREACHED).then_some(want));
    }
}
