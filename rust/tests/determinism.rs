//! Determinism suite for the pooled shard engine: for every app family,
//! the same fixed-seed query batch must produce identical `QueryResult::out`
//! across `threads ∈ {1, 4}` × `capacity ∈ {1, 8}`, and match the app's
//! serial oracle; the pool-specific matrix additionally sweeps
//! `threads ∈ {1, 2, 8}` × `workers ∈ {1, 3, 8}` (odd worker counts
//! exercise uneven destination sharding in the exchange phase). This pins
//! the core guarantee of the worker-shard design: thread count, worker
//! partitioning and admission schedule never change answers.

use quegel::apps::gkws::{self, query::GkwsQuery, KeywordSearch};
use quegel::apps::ppsp::{oracle as ppsp_oracle, Bfs, BiBfs, UNREACHED};
use quegel::apps::reach::{build_labels, condense, dag, ReachQuery};
use quegel::apps::terrain::baseline::dijkstra;
use quegel::apps::terrain::{Dem, TerrainNet, TerrainSssp};
use quegel::apps::xml::{self, SlcaLevelAligned, SlcaNaive};
use quegel::coordinator::{Admit, EdgeSplit, Engine, Layout, Pipeline, Sched, Split};
use quegel::graph::{gen, Graph, MutationBatch, VertexId};
use quegel::network::Cluster;
use quegel::vertex::{Ctx, QueryApp};

/// Run the same batch under every (threads, capacity) configuration and
/// assert all runs return identical per-query outputs (in submission
/// order). Returns one representative output vector for oracle checks.
fn run_configs<A, F>(mk: F, n: usize, workers: usize, queries: &[A::Query]) -> Vec<A::Out>
where
    A: QueryApp,
    A::Out: std::fmt::Debug + PartialEq,
    F: Fn() -> A,
{
    let mut base: Option<Vec<A::Out>> = None;
    for threads in [1usize, 4] {
        for capacity in [1usize, 8] {
            let mut eng = Engine::new(mk(), Cluster::new(workers), n)
                .capacity(capacity)
                .threads(threads);
            let ids: Vec<_> = queries.iter().map(|q| eng.submit(q.clone())).collect();
            eng.run_until_idle();
            assert_eq!(eng.results().len(), queries.len());
            let outs: Vec<A::Out> = ids
                .iter()
                .map(|id| {
                    eng.results()
                        .iter()
                        .find(|r| r.qid == *id)
                        .expect("query completed")
                        .out
                        .clone()
                })
                .collect();
            match &base {
                None => base = Some(outs),
                Some(b) => assert_eq!(
                    &outs, b,
                    "threads={threads} C={capacity} changed query outputs"
                ),
            }
        }
    }
    base.unwrap()
}

/// Pool-specific matrix: run the same batch across `threads` × `workers`
/// and assert every configuration returns bit-identical outputs (only
/// valid for apps whose output is independent of the partitioning, like
/// the ones used below). Returns one representative output vector.
fn run_matrix<A, F>(mk: F, n: usize, queries: &[A::Query]) -> Vec<A::Out>
where
    A: QueryApp,
    A::Out: std::fmt::Debug + PartialEq,
    F: Fn() -> A,
{
    let mut base: Option<Vec<A::Out>> = None;
    for workers in [1usize, 3, 8] {
        for threads in [1usize, 2, 8] {
            let mut eng = Engine::new(mk(), Cluster::new(workers), n)
                .capacity(8)
                .threads(threads);
            let ids: Vec<_> = queries.iter().map(|q| eng.submit(q.clone())).collect();
            eng.run_until_idle();
            assert_eq!(eng.results().len(), queries.len());
            let outs: Vec<A::Out> = ids
                .iter()
                .map(|id| {
                    eng.results()
                        .iter()
                        .find(|r| r.qid == *id)
                        .expect("query completed")
                        .out
                        .clone()
                })
                .collect();
            match &base {
                None => base = Some(outs),
                Some(b) => assert_eq!(
                    &outs, b,
                    "threads={threads} workers={workers} changed query outputs"
                ),
            }
        }
    }
    base.unwrap()
}

/// Scheduler sweep on the partition the stealing scheduler exists for:
/// `hub_concentrated` concentrates every high-degree vertex on worker 0,
/// so under `Sched::Stealing` lane 0's job is routinely finished by a
/// thief. Static chunks, per-item stealing jobs and the serial loop must
/// all return bit-identical outputs — the scheduler picks executors,
/// never merge or delivery orders.
#[test]
fn scheduler_choice_never_changes_outputs() {
    let n = 2_000;
    let g = gen::hub_concentrated(n, 8, 16, 3, 9201);
    let queries = gen::random_pairs(n, 10, 9202);
    let mut base: Option<Vec<Option<u32>>> = None;
    for sched in [Sched::Static, Sched::Stealing] {
        for threads in [1usize, 4, 8] {
            let mut eng = Engine::new(Bfs::new(&g), Cluster::new(8), n)
                .capacity(8)
                .threads(threads)
                .scheduler(sched);
            let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
            eng.run_until_idle();
            let outs: Vec<Option<u32>> = ids
                .iter()
                .map(|id| {
                    eng.results()
                        .iter()
                        .find(|r| r.qid == *id)
                        .expect("query completed")
                        .out
                })
                .collect();
            match &base {
                None => base = Some(outs),
                Some(b) => assert_eq!(
                    &outs, b,
                    "sched={sched:?} threads={threads} changed query outputs"
                ),
            }
        }
    }
    let outs = base.unwrap();
    for (i, &(s, t)) in queries.iter().enumerate() {
        let want = ppsp_oracle::bfs_dist(&g, s, t);
        assert_eq!(
            outs[i],
            (want != UNREACHED).then_some(want),
            "query ({s},{t})"
        );
    }
}

/// Layout sweep on the partition the flat layout exists for: the
/// hub-concentrated graph floods worker 0's staging and inbox, so the
/// arena/columnar path gets real volume. The flat slab-arena stores and
/// the hashed baseline maps must return bit-identical outputs across
/// threads and both pipeline modes, match the BFS oracle — and the flat
/// path must actually engage (the staging high-water gauge is its
/// engagement signal) while the hashed baseline must never touch it.
#[test]
fn layout_choice_never_changes_outputs() {
    let n = 2_000;
    let g = gen::hub_concentrated(n, 8, 16, 3, 9601);
    let queries = gen::random_pairs(n, 10, 9602);
    let mut base: Option<Vec<Option<u32>>> = None;
    for layout in [Layout::Hashed, Layout::Flat] {
        for threads in [1usize, 4] {
            for pipeline in [Pipeline::Off, Pipeline::On] {
                let mut eng = Engine::new(Bfs::new(&g), Cluster::new(8), n)
                    .capacity(8)
                    .threads(threads)
                    .scheduler(Sched::Stealing)
                    .pipeline(pipeline)
                    .layout(layout);
                let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
                eng.run_until_idle();
                let gauge = eng.metrics().staging_bytes_peak;
                match layout {
                    Layout::Flat => assert!(
                        gauge > 0,
                        "threads={threads} pipeline={pipeline:?}: flat layout never engaged"
                    ),
                    Layout::Hashed => assert_eq!(
                        gauge, 0,
                        "threads={threads} pipeline={pipeline:?}: hashed baseline \
                         touched the flat staging gauge"
                    ),
                }
                let outs: Vec<Option<u32>> = ids
                    .iter()
                    .map(|id| {
                        eng.results()
                            .iter()
                            .find(|r| r.qid == *id)
                            .expect("query completed")
                            .out
                    })
                    .collect();
                match &base {
                    None => base = Some(outs),
                    Some(b) => assert_eq!(
                        &outs, b,
                        "layout={layout:?} threads={threads} pipeline={pipeline:?} \
                         changed query outputs"
                    ),
                }
            }
        }
    }
    let outs = base.unwrap();
    for (i, &(s, t)) in queries.iter().enumerate() {
        let want = ppsp_oracle::bfs_dist(&g, s, t);
        assert_eq!(
            outs[i],
            (want != UNREACHED).then_some(want),
            "query ({s},{t})"
        );
    }
}

/// Combiner-less app whose answer depends on MESSAGE ORDER: the receiver
/// folds its inbox through the non-commutative `h -> h * 31 + m`. Three
/// senders are crafted so the fold only produces the locked constant when
/// delivery replays (a) worker-0's staging before worker-1's (the exchange
/// phase's source-worker order) and (b) worker-0's two senders in active-
/// list order (the compute phase's serial work order — exactly what the
/// sub-staging merge must reproduce when the task is split). Any silent
/// reordering anywhere in the staging/merge/exchange pipeline flips the
/// result.
struct OrderHash;

impl QueryApp for OrderHash {
    type Query = ();
    /// The receiver's fold accumulator (senders leave it 0).
    type VQ = u64;
    type Msg = u64;
    type Agg = ();
    type Out = u64;

    fn init_activate(&self, _q: &()) -> Vec<VertexId> {
        // Worker 0 (v mod 2 == 0) gets senders 0 then 2 in this order;
        // worker 1 gets sender 1. Vertex 3 (worker 1) is the receiver.
        vec![0, 2, 1]
    }

    fn init_value(&self, _q: &(), _v: VertexId) -> u64 {
        0
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, vq: &mut u64) {
        if ctx.superstep() == 1 {
            // Sender v contributes v + 1, all addressed to vertex 3.
            ctx.send(3, v as u64 + 1);
        } else {
            for &m in ctx.msgs() {
                *vq = *vq * 31 + m;
            }
        }
        ctx.vote_halt();
    }

    fn finish(
        &self,
        _q: &(),
        touched: &mut dyn Iterator<Item = (VertexId, &u64)>,
        _agg: &(),
    ) -> u64 {
        touched.find(|&(v, _)| v == 3).map(|(_, &h)| h).unwrap_or(0)
    }
}

/// In-source-order delivery is `[1, 3, 2]` (worker 0's senders 0 and 2 in
/// active order, then worker 1's sender 1), so the locked fold value is
/// `((0*31 + 1)*31 + 3)*31 + 2 = 1056`. The sweep includes a split
/// threshold of 1, which cuts worker 0's two-sender task into two
/// sub-jobs with separate staging buffers — the merge must replay them in
/// sub-range order or the constant flips — and both pipeline modes, since
/// the pipelined cascade's eager column handoff must replay the exact
/// same source-order delivery sequence as the barrier exchange.
#[test]
fn exchange_and_substaging_preserve_source_order() {
    // h0 = 1, h1 = 1*31 + 3 = 34, h2 = 34*31 + 2 = 1056.
    const WANT: u64 = (31 + 3) * 31 + 2;
    for threads in [1usize, 2] {
        for sched in [Sched::Static, Sched::Stealing] {
            for split in [Split::Off, Split::MaxTaskVertices(1), Split::Adaptive] {
                for edge in [EdgeSplit::Off, EdgeSplit::MaxFanout(1)] {
                    for pipeline in [Pipeline::Off, Pipeline::On] {
                        for layout in [Layout::Hashed, Layout::Flat] {
                            let mut eng = Engine::new(OrderHash, Cluster::new(2), 4)
                                .threads(threads)
                                .scheduler(sched)
                                .split(split)
                                .edge_split(edge)
                                .pipeline(pipeline)
                                .layout(layout);
                            let out = eng.run_one(()).out;
                            assert_eq!(
                                out, WANT,
                                "threads={threads} sched={sched:?} split={split:?} \
                                 edge={edge:?} pipeline={pipeline:?} \
                                 layout={layout:?} delivered out of source order"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Combiner-less app that pins the edge-split replay order INSIDE one
/// task: sender 0 stages a three-message fanout (parked and cut into
/// ranges whenever the edge threshold allows), then sender 2 — later in
/// the same task's serial order — stages one more message to the same
/// destination, which must land in the post-fan overflow segment and
/// replay AFTER every fan range. Receivers fold their inboxes through the
/// non-commutative `h -> h * 31 + m`, so any reordering between the
/// direct prefix, the fan ranges and the overflow tail flips the locked
/// constants.
struct OrderFan;

impl QueryApp for OrderFan {
    type Query = ();
    type VQ = u64;
    type Msg = u64;
    type Agg = ();
    /// (fold of vertex 3, fold of vertex 5).
    type Out = (u64, u64);

    fn init_activate(&self, _q: &()) -> Vec<VertexId> {
        // Both senders live on worker 0 (v mod 2 == 0), receivers 3 and 5
        // on worker 1; active order 0 then 2 is the serial work order.
        vec![0, 2]
    }

    fn init_value(&self, _q: &(), _v: VertexId) -> u64 {
        0
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, vq: &mut u64) {
        if ctx.superstep() == 1 {
            if v == 0 {
                // The fan: msgs to 3, 5, 3 in this exact send order.
                ctx.send(3, 1);
                ctx.send(5, 2);
                ctx.send(3, 3);
            } else {
                // The tail message, serially after the whole fan.
                ctx.send(3, 4);
            }
        } else {
            for &m in ctx.msgs() {
                *vq = *vq * 31 + m;
            }
        }
        ctx.vote_halt();
    }

    fn finish(
        &self,
        _q: &(),
        touched: &mut dyn Iterator<Item = (VertexId, &u64)>,
        _agg: &(),
    ) -> (u64, u64) {
        let mut out = (0, 0);
        for (v, &h) in touched {
            if v == 3 {
                out.0 = h;
            } else if v == 5 {
                out.1 = h;
            }
        }
        out
    }
}

/// Vertex 3 must fold `[1, 3, 4]` (fan order, then the tail): the locked
/// value is `((0*31 + 1)*31 + 3)*31 + 4 = 1058`; vertex 5 folds `[2]`.
/// `MaxFanout(2)` parks the fan and cuts it into ranges `[1, 2]` + `[3]`;
/// `MaxFanout(1)` dices it into three single-edge ranges; either way the
/// range-order fold and the overflow-tail replay must reproduce the
/// inline sequence exactly.
#[test]
fn edge_ranges_and_overflow_tail_replay_in_send_order() {
    const WANT: (u64, u64) = ((31 + 3) * 31 + 4, 2);
    let mut parked = false;
    for threads in [1usize, 2, 4] {
        for edge in [
            EdgeSplit::Off,
            EdgeSplit::MaxFanout(2),
            EdgeSplit::MaxFanout(1),
            EdgeSplit::Adaptive,
        ] {
            for pipeline in [Pipeline::Off, Pipeline::On] {
                for layout in [Layout::Hashed, Layout::Flat] {
                    let mut eng = Engine::new(OrderFan, Cluster::new(2), 6)
                        .threads(threads)
                        .scheduler(Sched::Stealing)
                        .edge_split(edge)
                        .pipeline(pipeline)
                        .layout(layout);
                    let out = eng.run_one(()).out;
                    parked |= eng.metrics().edge_ranges_split > 0;
                    assert_eq!(
                        out, WANT,
                        "threads={threads} edge={edge:?} pipeline={pipeline:?} \
                         layout={layout:?} replayed the fan or its tail out of \
                         send order"
                    );
                }
            }
        }
    }
    assert!(parked, "no configuration ever parked the fan");
}

/// Edge-split sweep on the partition the edge-level split exists for: the
/// mono-hub graph gives ONE vertex an out-edge to everyone, so the fan
/// superstep is a single `compute()` call staging ~n messages — no vertex
/// granularity can cut it. Unsplit, fixed-threshold and adaptive runs
/// must return bit-identical outputs and match the BFS oracle — and the
/// edge-range path must actually have engaged.
#[test]
fn edge_split_choice_never_changes_outputs() {
    let n = 3_000;
    let g = gen::mono_hub(n, 3, 9401);
    let queries = gen::random_pairs(n, 8, 9402);
    let mut base: Option<Vec<Option<u32>>> = None;
    let mut edge_ranges = 0u64;
    for edge in [EdgeSplit::Off, EdgeSplit::MaxFanout(40), EdgeSplit::Adaptive] {
        for threads in [1usize, 4] {
            let mut eng = Engine::new(Bfs::new(&g), Cluster::new(8), n)
                .capacity(8)
                .threads(threads)
                .scheduler(Sched::Stealing)
                .edge_split(edge);
            let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
            eng.run_until_idle();
            edge_ranges += eng.metrics().edge_ranges_split;
            let outs: Vec<Option<u32>> = ids
                .iter()
                .map(|id| {
                    eng.results()
                        .iter()
                        .find(|r| r.qid == *id)
                        .expect("query completed")
                        .out
                })
                .collect();
            match &base {
                None => base = Some(outs),
                Some(b) => assert_eq!(
                    &outs, b,
                    "edge={edge:?} threads={threads} changed query outputs"
                ),
            }
        }
    }
    assert!(edge_ranges > 0, "the sweep never executed an edge-range job");
    let outs = base.unwrap();
    for (i, &(s, t)) in queries.iter().enumerate() {
        let want = ppsp_oracle::bfs_dist(&g, s, t);
        assert_eq!(
            outs[i],
            (want != UNREACHED).then_some(want),
            "query ({s},{t})"
        );
    }
}

/// Split sweep on the partition the sub-lane split exists for: the
/// mega-hub graph concentrates one vertex's whole blast radius on worker
/// 0 as a single compute task, so `MaxTaskVertices(50)` reliably cuts it
/// into sub-jobs. Serial, lane-granular and sub-split runs must return
/// bit-identical outputs and match the BFS oracle — and the split path
/// must actually have engaged, so this can never silently test nothing.
#[test]
fn split_choice_never_changes_outputs() {
    let n = 3_000;
    let g = gen::mega_hub(n, 8, 5, 9301);
    let queries = gen::random_pairs(n, 8, 9302);
    let mut base: Option<Vec<Option<u32>>> = None;
    let mut subjobs = 0u64;
    for split in [Split::Off, Split::MaxTaskVertices(50), Split::Adaptive] {
        for threads in [1usize, 4] {
            let mut eng = Engine::new(Bfs::new(&g), Cluster::new(8), n)
                .capacity(8)
                .threads(threads)
                .scheduler(Sched::Stealing)
                .split(split);
            let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
            eng.run_until_idle();
            subjobs += eng.metrics().subjobs_executed;
            let outs: Vec<Option<u32>> = ids
                .iter()
                .map(|id| {
                    eng.results()
                        .iter()
                        .find(|r| r.qid == *id)
                        .expect("query completed")
                        .out
                })
                .collect();
            match &base {
                None => base = Some(outs),
                Some(b) => assert_eq!(
                    &outs, b,
                    "split={split:?} threads={threads} changed query outputs"
                ),
            }
        }
    }
    assert!(subjobs > 0, "the sweep never executed a sub-job");
    let outs = base.unwrap();
    for (i, &(s, t)) in queries.iter().enumerate() {
        let want = ppsp_oracle::bfs_dist(&g, s, t);
        assert_eq!(
            outs[i],
            (want != UNREACHED).then_some(want),
            "query ({s},{t})"
        );
    }
}

/// Pipeline sweep on the workload pipelining exists for: `one_slow_query`
/// pins one deep BFS to worker 0's lane while a crowd of point lookups
/// converges within a couple of supersteps. For every (threads, sched,
/// capacity) the barrier and ready-driven runs must return bit-identical
/// outputs AND an identical result sequence (qids in completion order —
/// deferred reporting must not reorder anything), all matching the BFS
/// oracle; the pipelined path must actually have engaged, and must never
/// engage under `Pipeline::Off` or on a serial engine.
#[test]
fn pipeline_choice_never_changes_outputs() {
    let n = 3_000;
    let stride = 4usize;
    let g = gen::one_slow_query(n, stride, 12, 20, 9501);
    // One slow query (the hub ladder grinds ~20 supersteps and never
    // reaches a star) among cheap star-to-star lookups.
    let fix = |v: u32| if v as usize % stride == 0 { v + 1 } else { v };
    let mut queries: Vec<(u32, u32)> = vec![(0, (n - 1) as u32)];
    for i in 0..12u32 {
        let s = fix((i * 211 + 1) % n as u32);
        let t = fix((i * 389 + 2) % n as u32);
        queries.push((s, t));
    }
    let mut engaged = 0u64;
    for threads in [1usize, 2, 4] {
        for sched in [Sched::Static, Sched::Stealing] {
            for capacity in [1usize, 8] {
                let mut runs: Vec<(Vec<Option<u32>>, Vec<u64>)> = Vec::new();
                for pipeline in [Pipeline::Off, Pipeline::On] {
                    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(stride), n)
                        .capacity(capacity)
                        .threads(threads)
                        .scheduler(sched)
                        .split(Split::Off)
                        .edge_split(EdgeSplit::Off)
                        .pipeline(pipeline);
                    let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
                    eng.run_until_idle();
                    let rounds = eng.metrics().pipelined_rounds;
                    match pipeline {
                        Pipeline::Off => assert_eq!(
                            rounds, 0,
                            "barrier mode must never take the pipelined path"
                        ),
                        Pipeline::On if threads == 1 => assert_eq!(
                            rounds, 0,
                            "a serial engine has nothing to overlap"
                        ),
                        Pipeline::On => engaged += rounds,
                    }
                    let order: Vec<u64> = eng.results().iter().map(|r| r.qid).collect();
                    let outs: Vec<Option<u32>> = ids
                        .iter()
                        .map(|id| {
                            eng.results()
                                .iter()
                                .find(|r| r.qid == *id)
                                .expect("query completed")
                                .out
                        })
                        .collect();
                    runs.push((outs, order));
                }
                assert_eq!(
                    runs[0], runs[1],
                    "threads={threads} sched={sched:?} C={capacity}: pipelining \
                     changed outputs or completion order"
                );
            }
        }
    }
    assert!(
        engaged > 0,
        "no threaded Pipeline::On configuration ever ran a pipelined round"
    );
    let outs: Vec<Option<u32>> = queries
        .iter()
        .map(|&(s, t)| {
            let want = ppsp_oracle::bfs_dist(&g, s, t);
            (want != UNREACHED).then_some(want)
        })
        .collect();
    // Any one run's outputs suffice for the oracle check (all are equal);
    // rebuild one cheaply at the sweep's smallest config.
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(stride), n)
        .capacity(8)
        .threads(4)
        .pipeline(Pipeline::On);
    let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
    eng.run_until_idle();
    for (i, id) in ids.iter().enumerate() {
        let got = eng
            .results()
            .iter()
            .find(|r| r.qid == *id)
            .expect("query completed")
            .out;
        assert_eq!(got, outs[i], "query {:?}", queries[i]);
    }
}

/// Plain BFS plus a deterministic whale flag for the admission planner:
/// a query is heavy iff its source is the slow ladder hub (vertex 0) or
/// its endpoint sum is odd — a pure function of the query, so every run
/// classifies identically. The BFS logic is byte-for-byte the library's
/// (`Ctx` is parameterized on the app type, so flagging can't wrap
/// `Bfs` by delegation).
struct FlaggedBfs<'g> {
    g: &'g Graph,
}

impl<'g> QueryApp for FlaggedBfs<'g> {
    type Query = (u32, u32);
    type VQ = u32;
    type Msg = ();
    type Agg = ();
    type Out = Option<u32>;

    fn is_heavy(&self, q: &(u32, u32)) -> bool {
        q.0 == 0 || (q.0 + q.1) % 2 == 1
    }

    fn init_activate(&self, q: &(u32, u32)) -> Vec<VertexId> {
        vec![q.0]
    }

    fn init_value(&self, q: &(u32, u32), v: VertexId) -> u32 {
        if v == q.0 {
            0
        } else {
            UNREACHED
        }
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, d: &mut u32) {
        let step = ctx.superstep();
        let (_, t) = *ctx.query();
        if step == 1 {
            if v == t {
                ctx.force_terminate();
            }
            for &u in self.g.out(v) {
                ctx.send(u, ());
            }
            ctx.vote_halt();
            return;
        }
        if *d == UNREACHED {
            *d = (step - 1) as u32;
            if v == t {
                ctx.force_terminate();
            } else {
                for &u in self.g.out(v) {
                    ctx.send(u, ());
                }
            }
        }
        ctx.vote_halt();
    }

    fn combine(&self, _into: &mut (), _from: &()) -> bool {
        true
    }

    fn finish(
        &self,
        q: &(u32, u32),
        touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &(),
    ) -> Option<u32> {
        let t = q.1;
        for (v, &d) in touched {
            if v == t && d != UNREACHED {
                return Some(d);
            }
        }
        None
    }
}

/// Admission sweep: the planner must decide only WHEN a query runs,
/// never what it computes. The one-slow-query workload carries 7 heavy
/// flags against a reserved slice of 2 (capacity 8), so `Admit::Adaptive`
/// genuinely defers whales while slots are free. For every
/// `Admit::{Static, Adaptive}` × threads × pipeline configuration the
/// per-query outputs must be bit-identical (and match the BFS oracle);
/// WITHIN each admission mode the result sequence (qids in completion
/// order) must also be identical across threads and pipeline — the
/// planner may legitimately reorder completions BETWEEN modes, which is
/// exactly why the fixed arrival trace pins the rest of the matrix.
/// Static admission must never defer; adaptive admission must defer at
/// least once somewhere in the sweep.
#[test]
fn admit_choice_never_changes_outputs() {
    let n = 3_000;
    let stride = 4usize;
    let g = gen::one_slow_query(n, stride, 12, 20, 9701);
    let fix = |v: u32| if v as usize % stride == 0 { v + 1 } else { v };
    let mut queries: Vec<(u32, u32)> = vec![(0, (n - 1) as u32)];
    for i in 0..12u32 {
        let s = fix((i * 211 + 1) % n as u32);
        let t = fix((i * 389 + 2) % n as u32);
        queries.push((s, t));
    }
    let mut base: Option<Vec<Option<u32>>> = None;
    let mut deferred = 0u64;
    for (ai, admit) in [Admit::Static(8), Admit::Adaptive].into_iter().enumerate() {
        let mut mode_order: Option<Vec<u64>> = None;
        for threads in [1usize, 4] {
            for pipeline in [Pipeline::Off, Pipeline::On] {
                let mut eng = Engine::new(FlaggedBfs { g: &g }, Cluster::new(stride), n)
                    .capacity(8)
                    .threads(threads)
                    .scheduler(Sched::Stealing)
                    .pipeline(pipeline)
                    .admit(admit);
                let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
                eng.run_until_idle();
                match admit {
                    Admit::Static(_) => assert_eq!(
                        eng.metrics().admit_deferrals,
                        0,
                        "static admission must never defer"
                    ),
                    Admit::Adaptive => deferred += eng.metrics().admit_deferrals,
                }
                let order: Vec<u64> = eng.results().iter().map(|r| r.qid).collect();
                match &mode_order {
                    None => mode_order = Some(order),
                    Some(o) => assert_eq!(
                        &order, o,
                        "admit#{ai} threads={threads} pipeline={pipeline:?}: \
                         completion order changed within one admission mode"
                    ),
                }
                let outs: Vec<Option<u32>> = ids
                    .iter()
                    .map(|id| {
                        eng.results()
                            .iter()
                            .find(|r| r.qid == *id)
                            .expect("query completed")
                            .out
                    })
                    .collect();
                match &base {
                    None => base = Some(outs),
                    Some(b) => assert_eq!(
                        &outs, b,
                        "admit={admit:?} threads={threads} pipeline={pipeline:?} \
                         changed query outputs"
                    ),
                }
            }
        }
    }
    assert!(
        deferred > 0,
        "Admit::Adaptive never deferred a heavy query — the planner did \
         not engage"
    );
    let outs = base.unwrap();
    for (i, &(s, t)) in queries.iter().enumerate() {
        let want = ppsp_oracle::bfs_dist(&g, s, t);
        assert_eq!(
            outs[i],
            (want != UNREACHED).then_some(want),
            "query ({s},{t})"
        );
    }
}

#[test]
fn pool_matrix_bibfs_bit_identical_across_threads_and_workers() {
    let mut g = gen::twitter_like(600, 5, 9101);
    g.ensure_in_edges();
    let queries = gen::random_pairs(600, 12, 9102);
    let outs = run_matrix(|| BiBfs::new(&g), 600, &queries);
    for (i, &(s, t)) in queries.iter().enumerate() {
        let want = ppsp_oracle::bfs_dist(&g, s, t);
        assert_eq!(
            outs[i],
            (want != UNREACHED).then_some(want),
            "query ({s},{t})"
        );
    }
}

#[test]
fn pool_matrix_xml_combinerless_bit_identical() {
    // SlcaNaive without its combiner is the exchange-heaviest workload:
    // every upward send hits the staging buffers in full, so uneven
    // destination sharding (workers = 3) gets real message volume.
    let t = xml::data::generate(&xml::XmlGenConfig {
        dblp_like: true,
        records: 120,
        vocab: 140,
        seed: 9111,
    });
    let queries = xml::data::query_pool(&t, 6, 2, 9112);
    let outs = run_matrix(|| SlcaNaive::without_combiner(&t), t.len(), &queries);
    for (i, q) in queries.iter().enumerate() {
        let got: Vec<u32> = outs[i].iter().map(|&(v, _, _)| v).collect();
        assert_eq!(got, xml::oracle::slca(&t, q), "q={q:?}");
    }
}

#[test]
fn ppsp_bibfs_deterministic_and_correct() {
    let mut g = gen::twitter_like(800, 5, 9001);
    g.ensure_in_edges();
    let queries = gen::random_pairs(800, 16, 9002);
    let outs = run_configs(|| BiBfs::new(&g), 800, 6, &queries);
    for (i, &(s, t)) in queries.iter().enumerate() {
        let want = ppsp_oracle::bfs_dist(&g, s, t);
        assert_eq!(
            outs[i],
            (want != UNREACHED).then_some(want),
            "query ({s},{t})"
        );
    }
}

#[test]
fn reach_deterministic_and_correct() {
    let g = gen::web_cyclic(700, 25, 3, 9011);
    let cond = condense(&g);
    let mut dagg = cond.dag.clone();
    dagg.ensure_in_edges();
    let (labels, _) = build_labels(&dagg, &Cluster::new(4), true);
    let pairs = gen::random_pairs(g.num_vertices(), 20, 9012);
    let queries: Vec<(u32, u32)> = pairs
        .iter()
        .map(|&(s, t)| (cond.scc_of[s as usize], cond.scc_of[t as usize]))
        .collect();
    let n = dagg.num_vertices();
    let outs = run_configs(|| ReachQuery::new(&dagg, &labels), n, 5, &queries);
    for (i, &(s, t)) in pairs.iter().enumerate() {
        assert_eq!(outs[i], dag::reaches(&g, s, t), "query ({s},{t})");
    }
}

#[test]
fn gkws_deterministic_and_correct() {
    let g = gkws::data::generate(&gkws::RdfGenConfig {
        resources: 500,
        avg_deg: 3,
        predicates: 20,
        vocab: 90,
        seed: 9021,
    });
    let queries: Vec<GkwsQuery> = gkws::data::query_pool(&g, 6, 2, 9022)
        .into_iter()
        .map(|keywords| GkwsQuery {
            keywords,
            delta_max: 3,
        })
        .collect();
    let outs = run_configs(|| KeywordSearch::new(&g), g.len(), 4, &queries);
    for (i, q) in queries.iter().enumerate() {
        let want = gkws::query::oracle(&g, q);
        // Hop values are unique; the matched entity may differ at ties
        // (both answers valid), so compare roots + per-keyword hops.
        let project = |rs: &[(u32, Vec<(u32, u32)>)]| -> Vec<(u32, Vec<u32>)> {
            rs.iter()
                .map(|(v, f)| (*v, f.iter().map(|&(_, h)| h).collect()))
                .collect()
        };
        assert_eq!(project(&outs[i]), project(&want), "query {i}");
    }
}

#[test]
fn xml_slca_deterministic_and_correct() {
    let t = xml::data::generate(&xml::XmlGenConfig {
        dblp_like: true,
        records: 150,
        vocab: 160,
        seed: 9031,
    });
    let queries = xml::data::query_pool(&t, 8, 2, 9032);
    let outs = run_configs(|| SlcaLevelAligned::new(&t), t.len(), 4, &queries);
    for (i, q) in queries.iter().enumerate() {
        let got: Vec<u32> = outs[i].iter().map(|&(v, _, _)| v).collect();
        assert_eq!(got, xml::oracle::slca(&t, q), "q={q:?}");
    }
}

#[test]
fn terrain_sssp_deterministic_and_correct() {
    let dem = Dem::fractal(14, 12, 10.0, 90.0, 9041);
    let net = TerrainNet::build(&dem, 5.0);
    let n = net.graph.num_vertices();
    let queries: Vec<(u32, u32)> = [
        (0usize, 0usize, 13usize, 11usize),
        (3, 2, 10, 9),
        (0, 11, 13, 0),
        (6, 6, 7, 7),
    ]
    .iter()
    .map(|&(sx, sy, tx, ty)| (net.corner(sx, sy), net.corner(tx, ty)))
    .collect();
    let outs = run_configs(|| TerrainSssp::new(&net), n, 4, &queries);
    for (i, &(s, t)) in queries.iter().enumerate() {
        let want = dijkstra(&net.graph, s, Some(t)).0[t as usize];
        assert!(outs[i].reached, "query {i} must reach its target");
        assert!(
            (outs[i].dist - want).abs() < 1e-6,
            "query {i}: {} vs dijkstra {want}",
            outs[i].dist
        );
    }
}

/// The serial snapshot-replay oracle for the mutation axis: drive a
/// mutating serving run — `try_submit` and `try_mutate` interleaved on the
/// simulated clock — and replay every completed query against plain serial
/// BFS on the materialized snapshot of the epoch it pinned at admission.
/// The snapshots come from [`Graph::apply`] folds, so no overlay machinery
/// is anywhere near the oracle side. Outputs must be a pure function of
/// (pinned version, query) for every engine configuration, and the axes
/// that cannot shift admission timing (threads, scheduler, layout) must
/// agree bit-for-bit on the `(epoch, out)` record stream as well.
#[test]
fn mutating_runs_replay_against_the_serial_snapshot_oracle() {
    use quegel::apps::ppsp::{vbfs_query, VersionedBfs};

    // CI matrix knob: the mutations-off leg proves the rest of the suite
    // is independent of the versioning machinery.
    if std::env::var("QUEGEL_TEST_MUT").is_ok_and(|v| v == "off") {
        eprintln!("QUEGEL_TEST_MUT=off: skipping mutation-schedule oracle test");
        return;
    }

    let n = 600usize;
    let g = gen::twitter_like(n, 5, 9801);

    // A fixed three-batch schedule: deletes drawn from arcs that exist,
    // adds between live vertices, one vertex add (wired both directions)
    // and one vertex delete.
    let mut b1 = MutationBatch::new();
    for v in [3u32, 57, 120] {
        if let Some(&u) = g.out(v).first() {
            b1.delete_edge(v, u);
        }
    }
    b1.add_edge(11, 503).add_edge(250, 9);
    let mut b2 = MutationBatch::new();
    b2.add_vertex().add_edge(n as u32, 42).add_edge(17, n as u32);
    for v in [200u32, 301] {
        if let Some(&u) = g.out(v).last() {
            b2.delete_edge(v, u);
        }
    }
    let mut b3 = MutationBatch::new();
    b3.delete_vertex(77).add_edge(5, 505);
    let batches = [b1, b2, b3];

    // folds[e] = the world at epoch e, by serial replay.
    let mut folds: Vec<Graph> = vec![g.clone()];
    for b in &batches {
        folds.push(folds.last().unwrap().apply(b));
    }

    // Wave w is submitted right after batch w is queued (wave 0 before
    // any mutation), so admitted queries span several pinned epochs.
    let waves: Vec<Vec<(u32, u32)>> = (0..=batches.len())
        .map(|w| gen::random_pairs(n, 6, 9810 + w as u64))
        .collect();
    let queries: Vec<(u32, u32)> = waves.iter().flatten().copied().collect();

    let run = |threads: usize, sched: Sched, pipeline: Pipeline, layout: Layout, admit: Admit| {
        let mut app = VersionedBfs::new(g.clone());
        app.heavy_every = 3; // content-derived whales for the Adaptive leg
        let mut eng = Engine::new(app, Cluster::new(4), n)
            .capacity(4)
            .threads(threads)
            .scheduler(sched)
            .pipeline(pipeline)
            .layout(layout)
            .admit(admit);
        let mut ids = Vec::new();
        for &(s, t) in &waves[0] {
            ids.push(eng.try_submit(vbfs_query(s, t), 0.0).expect("queue accepts"));
        }
        for (bi, b) in batches.iter().enumerate() {
            // Let earlier queries make progress (some stay in flight, so
            // old and new versions must coexist after the batch lands).
            eng.super_round();
            eng.super_round();
            eng.try_mutate(b.clone(), eng.sim_time())
                .expect("app supports mutations");
            for &(s, t) in &waves[bi + 1] {
                ids.push(
                    eng.try_submit(vbfs_query(s, t), eng.sim_time())
                        .expect("queue accepts"),
                );
            }
        }
        eng.run_until_idle();
        assert_eq!(eng.metrics().epochs_applied, 3);
        assert!(
            eng.metrics().delta_bytes_peak > 0,
            "delta overlay never engaged"
        );
        assert_eq!(eng.metrics().oldest_pinned_epoch, 3, "all pins retired");
        let recs: Vec<(u64, Option<u32>)> = ids
            .iter()
            .map(|id| {
                let r = eng
                    .results()
                    .iter()
                    .find(|r| r.qid == *id)
                    .expect("query completed");
                (r.stats.epoch, r.out)
            })
            .collect();
        // The oracle: every output equals serial BFS on the snapshot of
        // the epoch that query pinned.
        for (i, &(e, out)) in recs.iter().enumerate() {
            let (s, t) = queries[i];
            let want = ppsp_oracle::bfs_dist(&folds[e as usize], s, t);
            assert_eq!(
                out,
                (want != UNREACHED).then_some(want),
                "query ({s},{t}) at epoch {e}"
            );
        }
        // Version coexistence really happened: the record stream spans
        // both the pre-mutation world and the final epoch.
        assert!(recs.iter().any(|&(e, _)| e == 0));
        assert!(recs.iter().any(|&(e, _)| e == 3));
        recs
    };

    // Axes that cannot re-time admission must agree bit-for-bit on the
    // (pinned epoch, output) stream.
    let mut base: Option<Vec<(u64, Option<u32>)>> = None;
    for threads in [1usize, 4] {
        for sched in [Sched::Static, Sched::Stealing] {
            for layout in [Layout::Hashed, Layout::Flat] {
                let recs = run(threads, sched, Pipeline::Off, layout, Admit::Static(4));
                match &base {
                    None => base = Some(recs),
                    Some(b) => assert_eq!(
                        &recs, b,
                        "threads={threads} sched={sched:?} layout={layout:?}"
                    ),
                }
            }
        }
    }
    // Pipelining and adaptive admission may legitimately re-time
    // admission (and so re-pin epochs); the per-run oracle above still
    // gates their outputs.
    run(4, Sched::Stealing, Pipeline::On, Layout::Flat, Admit::Static(4));
    run(4, Sched::Stealing, Pipeline::Off, Layout::Hashed, Admit::Adaptive);
    run(4, Sched::Stealing, Pipeline::On, Layout::Flat, Admit::Adaptive);
}

/// Worker-process entrypoint for this test binary: the multi-process
/// tests spawn `current_exe()` filtered (`--exact`) to exactly this test,
/// whose body serves the remote worker protocol. In an ordinary
/// `cargo test` run the worker env knobs are absent and this passes as an
/// immediate no-op.
#[test]
fn multiproc_worker_entry() {
    quegel::coordinator::remote::maybe_serve_worker::<quegel::apps::ppsp::VersionedBfs>();
}

/// The process-count axis of the determinism contract: the full
/// mutation-schedule serving run — streaming `try_mutate` batches, four
/// submission waves pinning different epochs, the adaptive-vs-static
/// admission schedule — must produce a bit-identical `(epoch, out)`
/// record stream on a multi-process engine (coordinator + N worker
/// processes over localhost TCP) as on the in-process engine. Process
/// count joins threads/scheduler/layout as an axis that cannot re-time
/// admission, so the comparison is exact, not via the snapshot oracle.
#[test]
fn multiprocess_outputs_match_in_process_bit_for_bit() {
    use quegel::apps::ppsp::{vbfs_query, VersionedBfs};
    use quegel::coordinator::remote::{libtest_worker_args, procs_from_env, ProcEngine};
    use quegel::coordinator::EngineConfig;

    if std::env::var("QUEGEL_TEST_MUT").is_ok_and(|v| v == "off") {
        eprintln!("QUEGEL_TEST_MUT=off: skipping multi-process mutation test");
        return;
    }
    // QUEGEL_TEST_PROCS sets the worker-process count (CI matrix axis);
    // at least 2 so the wire path is always exercised here.
    let procs = procs_from_env().max(2);

    let n = 600usize;
    let g = gen::twitter_like(n, 5, 9801);
    let mut b1 = MutationBatch::new();
    for v in [3u32, 57, 120] {
        if let Some(&u) = g.out(v).first() {
            b1.delete_edge(v, u);
        }
    }
    b1.add_edge(11, 503).add_edge(250, 9);
    let mut b2 = MutationBatch::new();
    b2.add_vertex().add_edge(n as u32, 42).add_edge(17, n as u32);
    for v in [200u32, 301] {
        if let Some(&u) = g.out(v).last() {
            b2.delete_edge(v, u);
        }
    }
    let mut b3 = MutationBatch::new();
    b3.delete_vertex(77).add_edge(5, 505);
    let batches = [b1, b2, b3];
    let waves: Vec<Vec<(u32, u32)>> = (0..=batches.len())
        .map(|w| gen::random_pairs(n, 6, 9810 + w as u64))
        .collect();

    // The remote path is barrier-mode only, so both runs pin
    // Pipeline::Off; Static admission keeps the schedule framework-free.
    let cfg = EngineConfig {
        capacity: 4,
        threads: 1,
        pipeline: Pipeline::Off,
        layout: Layout::Flat,
        admit: Admit::Static(4),
        ..EngineConfig::default()
    };
    let mk_app = || {
        let mut app = VersionedBfs::new(g.clone());
        app.heavy_every = 3;
        app
    };

    // In-process reference run.
    let mut eng = Engine::with_config(mk_app(), Cluster::new(4), n, cfg);
    let mut want_ids = Vec::new();
    for &(s, t) in &waves[0] {
        want_ids.push(eng.try_submit(vbfs_query(s, t), 0.0).expect("queue accepts"));
    }
    for (bi, b) in batches.iter().enumerate() {
        eng.super_round();
        eng.super_round();
        eng.try_mutate(b.clone(), eng.sim_time()).expect("mutable app");
        for &(s, t) in &waves[bi + 1] {
            want_ids.push(
                eng.try_submit(vbfs_query(s, t), eng.sim_time())
                    .expect("queue accepts"),
            );
        }
    }
    eng.run_until_idle();
    let want: Vec<(u64, u64, Option<u32>)> = want_ids
        .iter()
        .map(|id| {
            let r = eng.results().iter().find(|r| r.qid == *id).expect("completed");
            (r.qid, r.stats.epoch, r.out)
        })
        .collect();

    // The same schedule through the multi-process engine.
    let mut pe = ProcEngine::new(
        mk_app(),
        Cluster::new(4),
        n,
        cfg,
        procs,
        &libtest_worker_args("multiproc_worker_entry"),
    );
    let mut got_ids = Vec::new();
    for &(s, t) in &waves[0] {
        got_ids.push(pe.try_submit(vbfs_query(s, t), 0.0).expect("queue accepts"));
    }
    for (bi, b) in batches.iter().enumerate() {
        pe.super_round();
        pe.super_round();
        pe.try_mutate(b.clone(), pe.sim_time()).expect("mutable app");
        for &(s, t) in &waves[bi + 1] {
            got_ids.push(
                pe.try_submit(vbfs_query(s, t), pe.sim_time())
                    .expect("queue accepts"),
            );
        }
    }
    pe.run_until_idle();
    assert_eq!(got_ids, want_ids, "submission ids must replay identically");
    let results = pe.take_results();
    let got: Vec<(u64, u64, Option<u32>)> = got_ids
        .iter()
        .map(|id| {
            let r = results.iter().find(|r| r.qid == *id).expect("completed");
            (r.qid, r.stats.epoch, r.out)
        })
        .collect();
    assert_eq!(
        got, want,
        "{procs}-process (epoch, out) stream must match in-process bit for bit"
    );
    assert!(
        pe.metrics().bytes_on_wire > 0,
        "multi-process run must put the exchange on the wire"
    );
    assert!(pe.metrics().rpc_round_trips > 0);
    assert_eq!(pe.metrics().queries_completed, want_ids.len() as u64);
    pe.shutdown();
}
