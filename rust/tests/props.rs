//! Property tests over the coordinator invariants (DESIGN.md §7), via the
//! in-repo `prop` harness (offline substitution for proptest).

use quegel::apps::ppsp::hub2::{Hub2Indexer, Hub2Query, RustMinPlus};
use quegel::apps::ppsp::{oracle, Bfs, BiBfs, UNREACHED};
use quegel::apps::reach::{build_labels, condense, ReachQuery};
use quegel::apps::xml;
use quegel::coordinator::Engine;
use quegel::graph::{gen, Graph};
use quegel::network::Cluster;
use quegel::prop;
use quegel::util::Rng;
use quegel::{prop_assert, prop_assert_eq};

fn random_graph(rng: &mut Rng) -> Graph {
    let n = 100 + rng.below_usize(400);
    let deg = 2 + rng.below_usize(5);
    match rng.below(3) {
        0 => gen::twitter_like(n, deg, rng.next_u64()),
        1 => gen::btc_like(n, 10 + rng.below_usize(40), deg, rng.next_u64()),
        _ => gen::livej_like(n, n / 5 + 2, deg, rng.next_u64()),
    }
}

/// (i) Superstep-sharing is answer-preserving for any capacity.
#[test]
fn prop_sharing_invariant_under_capacity() {
    prop::check("sharing-capacity", 12, |rng| {
        let g = random_graph(rng);
        let n = g.num_vertices();
        let queries = gen::random_pairs(n, 4 + rng.below_usize(8), rng.next_u64());
        let workers = 1 + rng.below_usize(7);
        let mut base: Option<Vec<Option<u32>>> = None;
        for c in [1usize, 3, 8] {
            let mut eng = Engine::new(Bfs::new(&g), Cluster::new(workers), n).capacity(c);
            let ids: Vec<_> = queries.iter().map(|&q| eng.submit(q)).collect();
            eng.run_until_idle();
            let mut outs = Vec::new();
            for id in &ids {
                outs.push(eng.results().iter().find(|r| r.qid == *id).unwrap().out);
            }
            match &base {
                None => base = Some(outs),
                Some(b) => prop_assert_eq!(&outs, b, "capacity {} changed answers", c),
            }
        }
        Ok(())
    });
}

/// (ii) Lazy VQ-data: the touched set equals what BFS can actually reach.
#[test]
fn prop_lazy_state_bounded_by_reachable_set() {
    prop::check("lazy-vq", 15, |rng| {
        let g = random_graph(rng);
        let n = g.num_vertices();
        let (s, t) = gen::random_pairs(n, 1, rng.next_u64())[0];
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), n);
        let r = eng.run_one((s, t));
        // Reachable set from s (+1 for t's possible lazy init).
        let dists = oracle::bfs_all(&g, s);
        let reachable = dists.iter().filter(|&&d| d != UNREACHED).count() as u64;
        prop_assert!(
            r.stats.touched <= reachable + 1,
            "touched {} > reachable {}",
            r.stats.touched,
            reachable + 1
        );
        prop_assert!(r.stats.touched >= 1, "s must always be touched");
        Ok(())
    });
}

/// (iii) Worker partition is total: answers independent of worker count.
#[test]
fn prop_worker_count_invariance() {
    prop::check("worker-invariance", 10, |rng| {
        let g = random_graph(rng);
        let n = g.num_vertices();
        let (s, t) = gen::random_pairs(n, 1, rng.next_u64())[0];
        let mut outs = Vec::new();
        for w in [1usize, 2, 7, 16] {
            let mut eng = Engine::new(Bfs::new(&g), Cluster::new(w), n);
            outs.push(eng.run_one((s, t)).out);
        }
        prop_assert!(
            outs.windows(2).all(|p| p[0] == p[1]),
            "answers vary with workers: {:?}",
            outs
        );
        Ok(())
    });
}

/// (iv) BFS / BiBFS / Hub² / serial oracle all agree.
#[test]
fn prop_ppsp_algorithms_agree() {
    prop::check("ppsp-agree", 8, |rng| {
        let mut g = random_graph(rng);
        g.ensure_in_edges();
        let n = g.num_vertices();
        // Keep the rng draw (downstream seeds depend on the call order);
        // graphs here store both arcs only for btc/livej, so treat every
        // graph as directed uniformly.
        let _undirected = rng.chance(0.5);
        let idx = Hub2Indexer::new(8 + rng.below_usize(12))
            .undirected(false)
            .build(&g, Cluster::new(4), &RustMinPlus)
            .0;
        for (s, t) in gen::random_pairs(n, 6, rng.next_u64()) {
            let want = oracle::bfs_dist(&g, s, t);
            let expect = (want != UNREACHED).then_some(want);
            let mut e1 = Engine::new(Bfs::new(&g), Cluster::new(3), n);
            prop_assert_eq!(e1.run_one((s, t)).out, expect, "bfs ({},{})", s, t);
            let mut e2 = Engine::new(BiBfs::new(&g), Cluster::new(3), n);
            prop_assert_eq!(e2.run_one((s, t)).out, expect, "bibfs ({},{})", s, t);
            let dub = idx.dub_for(&[(s, t)], &RustMinPlus, 1, idx.k())[0];
            let mut e3 = Engine::new(Hub2Query::new(&g, &idx), Cluster::new(3), n);
            prop_assert_eq!(e3.run_one((s, t, dub)).out, expect, "hub2 ({},{})", s, t);
        }
        Ok(())
    });
}

/// (v) Reachability with label pruning ≡ serial reachability oracle.
#[test]
fn prop_reach_labels_sound_and_complete() {
    prop::check("reach-labels", 8, |rng| {
        let n = 200 + rng.below_usize(400);
        let layers = 8 + rng.below_usize(20);
        let g = gen::web_cyclic(n.max(layers * 3), layers, 2 + rng.below_usize(3), rng.next_u64());
        let cond = condense(&g);
        let mut dag = cond.dag.clone();
        if dag.num_vertices() < 2 {
            return Ok(());
        }
        dag.ensure_in_edges();
        let (labels, _) = build_labels(&dag, &Cluster::new(4), rng.chance(0.5));
        let app = ReachQuery::new(&dag, &labels);
        let mut eng = Engine::new(app, Cluster::new(4), dag.num_vertices());
        for (s, t) in gen::random_pairs(g.num_vertices(), 10, rng.next_u64()) {
            let want = quegel::apps::reach::dag::reaches(&g, s, t);
            let dq = (cond.scc_of[s as usize], cond.scc_of[t as usize]);
            let got = eng.run_one(dq).out;
            prop_assert_eq!(got, want, "({},{})", s, t);
        }
        Ok(())
    });
}

/// (vi) XML: naive SLCA ≡ level-aligned SLCA ≡ oracle on random corpora.
#[test]
fn prop_xml_slca_variants_agree() {
    prop::check("xml-slca", 8, |rng| {
        let t = xml::data::generate(&xml::XmlGenConfig {
            dblp_like: rng.chance(0.5),
            records: 40 + rng.below_usize(120),
            vocab: 60 + rng.below_usize(100),
            seed: rng.next_u64(),
        });
        let m = 2 + rng.below_usize(2);
        for q in xml::data::query_pool(&t, 5, m, rng.next_u64()) {
            let want = xml::oracle::slca(&t, &q);
            let mut e1 = Engine::new(xml::SlcaNaive::new(&t), Cluster::new(4), t.len());
            let got1: Vec<u32> = e1.run_one(q.clone()).out.iter().map(|&(v, _, _)| v).collect();
            prop_assert_eq!(&got1, &want, "naive q={:?}", q);
            let mut e2 = Engine::new(xml::SlcaLevelAligned::new(&t), Cluster::new(4), t.len());
            let got2: Vec<u32> = e2.run_one(q.clone()).out.iter().map(|&(v, _, _)| v).collect();
            prop_assert_eq!(&got2, &want, "aligned q={:?}", q);
        }
        Ok(())
    });
}

/// (vii) Message accounting: bytes scale with messages; combiner only
/// reduces, never increases, traffic.
#[test]
fn prop_combiner_only_reduces_messages() {
    prop::check("combiner-traffic", 10, |rng| {
        let g = random_graph(rng);
        let n = g.num_vertices();
        let (s, t) = gen::random_pairs(n, 1, rng.next_u64())[0];
        let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), n);
        let r = eng.run_one((s, t));
        // Post-combiner messages can never exceed edges scanned.
        let scanned: u64 = g.num_edges() as u64;
        prop_assert!(
            r.stats.messages <= scanned,
            "messages {} > edges {}",
            r.stats.messages,
            scanned
        );
        prop_assert!(r.stats.bytes >= r.stats.messages, "bytes below messages");
        Ok(())
    });
}
