//! Engine drop-tests for the persistent work-stealing pool: dropping an
//! `Engine` mid-queue (queries still queued and in flight) must shut the
//! pool down cleanly — every worker thread joined, none leaked — and a
//! panicking `compute()` must re-raise its original payload on the
//! coordinator (whether the job ran on its home thread or was stolen)
//! while leaving the pool joinable during the ensuing unwind.
//!
//! This lives in its own integration-test binary, as a single `#[test]`
//! running serialized scenarios, on purpose: tests within one binary run
//! concurrently and other suites also spawn engine pools, which would
//! make a process-wide thread count race-prone. Cargo runs test binaries
//! one at a time, so the counts observed here are stable.

use quegel::apps::ppsp::{Bfs, BiBfs};
use quegel::coordinator::Engine;
use quegel::graph::{gen, Graph, VertexId};
use quegel::network::Cluster;
use quegel::vertex::{Ctx, QueryApp};

/// Current thread count of this process (Linux); None where /proc is
/// unavailable, in which case the assertions degrade to "drop returns".
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

/// Poll until the thread count drops back to `want` (worker teardown is
/// synchronous via join, but give the kernel a moment to reap).
fn settles_to(want: usize) -> bool {
    for _ in 0..200 {
        match process_threads() {
            None => return true,
            Some(n) if n <= want => return true,
            Some(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    false
}

#[test]
fn engine_drop_and_reconfigure_join_pool_threads() {
    // Scenario 1: drop mid-queue. The pool must wake, stop and join its
    // workers even with queries still queued and in flight.
    let before = process_threads();
    {
        let mut g = gen::twitter_like(400, 4, 9121);
        g.ensure_in_edges();
        let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(8), 400)
            .capacity(2)
            .threads(8);
        for q in gen::random_pairs(400, 16, 9122) {
            eng.submit(q);
        }
        eng.super_round();
        eng.super_round();
        assert!(
            eng.results().len() < 16,
            "test must drop the engine mid-queue, not after completion"
        );
    }
    if let Some(before) = before {
        assert!(
            settles_to(before),
            "pool leaked threads past engine drop: before={before}, after={:?}",
            process_threads()
        );
    }

    // Scenario 2: reconfiguring `threads` drops (joins) the old pool
    // before the next super-round spawns the new one — no accumulation.
    let before = process_threads();
    let g = gen::twitter_like(300, 4, 9131);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 300).threads(4);
    let (s, t) = gen::random_pairs(300, 1, 9132)[0];
    let first = eng.run_one((s, t));
    let mut eng = eng.threads(2);
    let second = eng.run_one((s, t));
    assert_eq!(first.out, second.out);
    drop(eng);
    if let Some(before) = before {
        assert!(
            settles_to(before),
            "threads() reconfiguration leaked workers: before={before}, after={:?}",
            process_threads()
        );
    }

    // Scenario 3: a panicking compute() job (home-run or stolen) must
    // re-raise its original payload on the coordinator, and the ensuing
    // unwind drops the engine mid-flight — which must still join every
    // pool worker.
    let before = process_threads();
    let g = gen::twitter_like(500, 4, 9141);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut eng = Engine::new(Poisoned { g: &g, poison: 123 }, Cluster::new(8), 500)
            .capacity(4)
            .threads(8);
        eng.submit(0);
        eng.run_until_idle();
    }));
    let payload = result.expect_err("a poisoned compute must fail the run");
    let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
    assert!(
        msg.contains("expected in test"),
        "original panic payload must cross the pool barrier, got {msg:?}"
    );
    if let Some(before) = before {
        assert!(
            settles_to(before),
            "panic-unwound engine leaked pool threads: before={before}, after={:?}",
            process_threads()
        );
    }
}

/// Flood app whose `compute` panics when the flood reaches the poison
/// vertex — from the pool's point of view, an arbitrary job (home-run or
/// stolen, depending on scheduling) that unwinds mid-phase.
struct Poisoned<'g> {
    g: &'g Graph,
    poison: VertexId,
}

impl<'g> QueryApp for Poisoned<'g> {
    /// Flood source vertex.
    type Query = VertexId;
    /// Superstep at which the flood arrived (0 = untouched).
    type VQ = u32;
    type Msg = ();
    type Agg = ();
    type Out = u64;

    fn init_activate(&self, q: &VertexId) -> Vec<VertexId> {
        vec![*q]
    }

    fn init_value(&self, _q: &VertexId, _v: VertexId) -> u32 {
        0
    }

    fn compute(&self, ctx: &mut Ctx<'_, Self>, v: VertexId, d: &mut u32) {
        if v == self.poison {
            panic!("poisoned vertex hit (expected in test)");
        }
        if *d == 0 {
            *d = ctx.superstep() as u32;
            for &w in self.g.out(v) {
                ctx.send(w, ());
            }
        }
        ctx.vote_halt();
    }

    fn finish(
        &self,
        _q: &VertexId,
        touched: &mut dyn Iterator<Item = (VertexId, &u32)>,
        _agg: &(),
    ) -> u64 {
        touched.count() as u64
    }
}
