//! Engine drop-test for the persistent worker pool: dropping an `Engine`
//! mid-queue (queries still queued and in flight) must shut the pool down
//! cleanly — every worker thread joined, none leaked.
//!
//! This lives in its own integration-test binary, as a single `#[test]`,
//! on purpose: tests within one binary run concurrently and other suites
//! also spawn engine pools, which would make a process-wide thread count
//! race-prone. Cargo runs test binaries one at a time, so the counts
//! observed here are stable.

use quegel::apps::ppsp::{Bfs, BiBfs};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::network::Cluster;

/// Current thread count of this process (Linux); None where /proc is
/// unavailable, in which case the assertions degrade to "drop returns".
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

/// Poll until the thread count drops back to `want` (worker teardown is
/// synchronous via join, but give the kernel a moment to reap).
fn settles_to(want: usize) -> bool {
    for _ in 0..200 {
        match process_threads() {
            None => return true,
            Some(n) if n <= want => return true,
            Some(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    false
}

#[test]
fn engine_drop_and_reconfigure_join_pool_threads() {
    // Scenario 1: drop mid-queue. The pool must wake, stop and join its
    // workers even with queries still queued and in flight.
    let before = process_threads();
    {
        let mut g = gen::twitter_like(400, 4, 9121);
        g.ensure_in_edges();
        let mut eng = Engine::new(BiBfs::new(&g), Cluster::new(8), 400)
            .capacity(2)
            .threads(8);
        for q in gen::random_pairs(400, 16, 9122) {
            eng.submit(q);
        }
        eng.super_round();
        eng.super_round();
        assert!(
            eng.results().len() < 16,
            "test must drop the engine mid-queue, not after completion"
        );
    }
    if let Some(before) = before {
        assert!(
            settles_to(before),
            "pool leaked threads past engine drop: before={before}, after={:?}",
            process_threads()
        );
    }

    // Scenario 2: reconfiguring `threads` drops (joins) the old pool
    // before the next super-round spawns the new one — no accumulation.
    let before = process_threads();
    let g = gen::twitter_like(300, 4, 9131);
    let mut eng = Engine::new(Bfs::new(&g), Cluster::new(4), 300).threads(4);
    let (s, t) = gen::random_pairs(300, 1, 9132)[0];
    let first = eng.run_one((s, t));
    let mut eng = eng.threads(2);
    let second = eng.run_one((s, t));
    assert_eq!(first.out, second.out);
    drop(eng);
    if let Some(before) = before {
        assert!(
            settles_to(before),
            "threads() reconfiguration leaked workers: before={before}, after={:?}",
            process_threads()
        );
    }
}
