//! Integration: load AOT artifacts, execute on the PJRT CPU client, and
//! check numerics against hand-computed min-plus results.
//!
//! Skips (with a message) if `artifacts/` has not been built yet; run
//! `make artifacts` first. The whole suite requires the `pjrt` cargo
//! feature (the default offline build has no PJRT runtime).
#![cfg(feature = "pjrt")]

use quegel::runtime::Runtime;

const INF: f32 = 2147483648.0; // 2^31, matches python/compile/kernels/ref.py

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

#[test]
fn hub_closure_roundtrip() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let exe = rt
        .load_hlo_text(dir.join("hub_closure_k128.hlo.txt"))
        .expect("load artifact");

    // Hub table: path 0 -> 1 -> 2 with weights 3 and 4; closure must find
    // d(0, 2) = 7 after one squaring step.
    let k = 128usize;
    let mut d = vec![INF; k * k];
    for i in 0..k {
        d[i * k + i] = 0.0;
    }
    d[1] = 3.0; // d[0][1]
    d[k + 2] = 4.0; // d[1][2]
    let out = exe.run_f32(&[(&d, &[k, k])]).expect("execute");
    assert_eq!(out.len(), 1);
    let c = &out[0];
    assert_eq!(c[1], 3.0);
    assert_eq!(c[k + 2], 4.0);
    assert_eq!(c[2], 7.0, "closure must compose 0->1->2");
    assert_eq!(c[5 * k + 9], INF, "untouched pairs stay INF");
}

#[test]
fn dub_batch_roundtrip() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let exe = rt
        .load_hlo_text(dir.join("dub_batch_c8_k128.hlo.txt"))
        .expect("load artifact");

    let (c, k) = (8usize, 128usize);
    let mut s = vec![INF; c * k];
    let mut t = vec![INF; c * k];
    let mut d = vec![INF; k * k];
    for i in 0..k {
        d[i * k + i] = 0.0;
    }
    // Query 0: s is 2 from hub 3; t is 5 from hub 7; d(3, 7) = 10.
    s[3] = 2.0;
    t[7] = 5.0;
    d[3 * k + 7] = 10.0;
    // Query 1: s and t share hub 4 (d(4,4) = 0): 1 + 0 + 1 = 2.
    s[k + 4] = 1.0;
    t[k + 4] = 1.0;

    let out = exe
        .run_f32(&[(&s, &[c, k]), (&d, &[k, k]), (&t, &[c, k])])
        .expect("execute");
    let dub = &out[0];
    assert_eq!(dub.len(), c);
    assert_eq!(dub[0], 17.0);
    assert_eq!(dub[1], 2.0);
    for q in 2..c {
        assert_eq!(dub[q], INF, "padding rows must stay INF");
    }
}
