//! Cross-layer integration: the PJRT-compiled Pallas kernels against the
//! rust oracle, the full Hub² pipeline through the artifacts, and the
//! terrain CH-baseline vs Quegel path-shape comparison.

use quegel::apps::ppsp::hub2::{Hub2Indexer, Hub2Query, RustMinPlus};
use quegel::apps::ppsp::{oracle, UNREACHED};
use quegel::apps::terrain::baseline::{hausdorff, ChResult, ChenHanStandIn};
use quegel::apps::terrain::{Dem, TerrainNet, TerrainSssp};
use quegel::coordinator::Engine;
use quegel::graph::gen;
use quegel::network::Cluster;
#[cfg(feature = "pjrt")]
use quegel::{
    apps::ppsp::hub2::{from_f, MinPlus, F_INF},
    runtime::minplus::PjrtMinPlus,
    runtime::Runtime,
    util::Rng,
};

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_minplus_matches_rust_oracle() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let rt = Runtime::cpu().expect("pjrt client");
    let mp = PjrtMinPlus::load(&rt, &dir, 64).expect("load artifacts");
    let mut rng = Rng::new(42);

    // Random closure tables.
    for k in [5usize, 17, 64] {
        let mut d = vec![F_INF; k * k];
        for i in 0..k {
            d[i * k + i] = 0.0;
        }
        for _ in 0..k * 3 {
            let i = rng.below_usize(k);
            let j = rng.below_usize(k);
            let w = (1 + rng.below(30)) as f32;
            if i != j && w < d[i * k + j] {
                d[i * k + j] = w;
            }
        }
        let mut want = d.clone();
        RustMinPlus.closure(&mut want, k);
        let mut got = d.clone();
        mp.closure(&mut got, k);
        assert_eq!(got, want, "closure k={k}");
    }

    // Random dub batches.
    for (c, k) in [(1usize, 8usize), (8, 32), (13, 64)] {
        let gen_rows = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len)
                .map(|_| {
                    if rng.chance(0.3) {
                        F_INF
                    } else {
                        rng.below(50) as f32
                    }
                })
                .collect()
        };
        let s = gen_rows(&mut rng, c * k);
        let t = gen_rows(&mut rng, c * k);
        let mut d = gen_rows(&mut rng, k * k);
        for i in 0..k {
            d[i * k + i] = 0.0;
        }
        let want = RustMinPlus.dub_batch(&s, &d, &t, c, k);
        let got = mp.dub_batch(&s, &d, &t, c, k);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(from_f(*g), from_f(*w), "dub[{i}] c={c} k={k}");
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn hub2_pipeline_through_pjrt_artifacts() {
    // The L1-on-the-hot-path test: index + batched d_ub through the
    // compiled Pallas kernel, answers checked against the serial oracle.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let rt = Runtime::cpu().expect("pjrt client");
    let mp = PjrtMinPlus::load(&rt, &dir, 128).expect("load artifacts");

    let mut g = gen::twitter_like(1_500, 6, 301);
    g.ensure_in_edges();
    let (idx, _) = Hub2Indexer::new(32).build(&g, Cluster::new(4), &mp);
    let queries = gen::random_pairs(1_500, 24, 302);
    let dubs = idx.dub_for(&queries, &mp, mp.c, mp.k);

    let mut eng = Engine::new(Hub2Query::new(&g, &idx), Cluster::new(4), 1_500).capacity(8);
    let ids: Vec<_> = queries
        .iter()
        .zip(&dubs)
        .map(|(&(s, t), &dub)| eng.submit((s, t, dub)))
        .collect();
    eng.run_until_idle();
    for (i, id) in ids.iter().enumerate() {
        let r = eng.results().iter().find(|r| r.qid == *id).unwrap();
        let want = oracle::bfs_dist(&g, queries[i].0, queries[i].1);
        assert_eq!(
            r.out,
            (want != UNREACHED).then_some(want),
            "query {i} {:?}",
            queries[i]
        );
    }
}

#[test]
fn terrain_quegel_path_tracks_ch_baseline() {
    // Table 10's HDist claim: the two paths have similar length and shape.
    let dem = Dem::fractal(40, 36, 10.0, 120.0, 303);
    let net = TerrainNet::build(&dem, 2.5);
    let ch = ChenHanStandIn::new(&dem);

    let mut eng = Engine::new(TerrainSssp::new(&net), Cluster::new(4), net.graph.num_vertices());
    for (tx, ty) in [(4usize, 4usize), (8, 8), (16, 12)] {
        let s = net.corner(0, 0);
        let t = net.corner(tx, ty);
        let out = eng.run_one((s, t)).out;
        assert!(out.reached);
        match ch.query(0, 0, tx, ty) {
            ChResult::Ok { len, path, .. } => {
                let rel = (out.dist - len).abs() / len;
                assert!(
                    rel < 0.05,
                    "length mismatch: quegel {} vs CH {len} ({rel:.3})",
                    out.dist
                );
                let h = hausdorff(&out.path, &path);
                assert!(
                    h < 25.0,
                    "paths diverge: HDist {h:.1} m for ({tx},{ty})"
                );
            }
            ChResult::Oom => panic!("CH must handle short queries"),
        }
    }
}

#[test]
fn e2e_mixed_apps_share_one_binary() {
    // Smoke: every app family runs back-to-back in one process (no global
    // state leaks between engines).
    let mut g = gen::btc_like(400, 30, 4, 304);
    g.ensure_in_edges();
    let (idx, _) = Hub2Indexer::new(8)
        .undirected(true)
        .build(&g, Cluster::new(2), &RustMinPlus);
    let q = gen::random_pairs(400, 3, 305);
    for &(s, t) in &q {
        let dub = idx.dub_for(&[(s, t)], &RustMinPlus, 1, idx.k())[0];
        let mut eng = Engine::new(Hub2Query::new(&g, &idx), Cluster::new(2), 400);
        let want = oracle::bfs_dist(&g, s, t);
        assert_eq!(
            eng.run_one((s, t, dub)).out,
            (want != UNREACHED).then_some(want)
        );
    }

    let t = quegel::apps::xml::data::generate(&quegel::apps::xml::XmlGenConfig {
        dblp_like: true,
        records: 50,
        vocab: 80,
        seed: 306,
    });
    let queries = quegel::apps::xml::data::query_pool(&t, 3, 2, 307);
    for q in queries {
        let want = quegel::apps::xml::oracle::slca(&t, &q);
        let mut eng = Engine::new(
            quegel::apps::xml::SlcaLevelAligned::new(&t),
            Cluster::new(2),
            t.len(),
        );
        let got: Vec<u32> = eng.run_one(q).out.iter().map(|&(v, _, _)| v).collect();
        assert_eq!(got, want);
    }
}
